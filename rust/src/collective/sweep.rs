//! The collective sweep engine: fan the (collective × nodes × gpn × size)
//! grid out over the in-tree worker pool, evaluate every algorithm variant
//! per point through the composed Table 6 models and (optionally) the
//! discrete-event simulator, and collect results in a deterministic order.
//!
//! Same determinism contract as [`crate::sweep`]: given the same
//! [`CollectiveConfig`] (including `seed`), two runs produce byte-identical
//! emitter output regardless of thread count — cells are seeded by index
//! and results land in pre-sized per-cell slots in grid order.

use super::bounds::ColBoundModel;
use super::report::{analyze, CollectiveReport};
use super::{lower, model, sim_schedule, Collective, CollectiveAlgorithm, CollectiveSpec};
use crate::params::{CompiledParams, MachineParams};
use crate::sim;
use crate::sweep::engine::{refine_2d, PlaneGeom};
use crate::topology::{machines, Machine};
use crate::util::pool;
use crate::util::pool::effective_threads;
use crate::util::rng::index_seed as cell_seed;
use std::time::Instant;

/// The collective grid: every combination of the axes below is one cell,
/// and every cell is evaluated for every selected algorithm.
#[derive(Clone, Debug, PartialEq)]
pub struct CollectiveGrid {
    /// Collectives to sweep.
    pub collectives: Vec<Collective>,
    /// Algorithm variants evaluated at every grid point.
    pub algorithms: Vec<CollectiveAlgorithm>,
    /// Node counts (every process participates — no extra sender node).
    pub nodes: Vec<usize>,
    /// GPUs per node (even: the preset node keeps its 2 sockets).
    pub gpus_per_node: Vec<usize>,
    /// Per-pair block sizes in bytes (alltoallv jitters around them).
    pub sizes: Vec<usize>,
}

impl Default for CollectiveGrid {
    fn default() -> CollectiveGrid {
        CollectiveGrid {
            collectives: Collective::ALL.to_vec(),
            algorithms: CollectiveAlgorithm::ALL.to_vec(),
            nodes: vec![2, 8, 32],
            gpus_per_node: vec![4],
            sizes: (9..=19).step_by(2).map(|e| 1usize << e).collect(),
        }
    }
}

/// One unit of collective sweep work: a fully-specified grid point (all
/// algorithms are evaluated inside the cell so the direct pattern is
/// synthesized once).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ColCellSpec {
    /// Position in [`CollectiveGrid::cells`] — drives the per-cell seed and
    /// the deterministic output order.
    pub index: usize,
    pub collective: Collective,
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub size: usize,
}

impl CollectiveGrid {
    /// A sub-second grid for CI smoke tests that still exercises every
    /// collective and algorithm on both sides of the small/large band.
    pub fn tiny() -> CollectiveGrid {
        CollectiveGrid {
            collectives: Collective::ALL.to_vec(),
            algorithms: CollectiveAlgorithm::ALL.to_vec(),
            nodes: vec![2, 4],
            gpus_per_node: vec![4],
            sizes: vec![512, 1 << 14],
        }
    }

    /// Check axis sanity; returns a user-facing message on failure.
    pub fn validate(&self) -> Result<(), String> {
        if self.collectives.is_empty() {
            return Err("no collectives selected".into());
        }
        if self.algorithms.is_empty() {
            return Err("no collective algorithms selected".into());
        }
        if self.nodes.is_empty() || self.nodes.iter().any(|&n| n < 2) {
            return Err("collective node counts must be non-empty and >= 2".into());
        }
        if self.gpus_per_node.is_empty() || self.gpus_per_node.iter().any(|&g| g < 2 || g % 2 != 0) {
            return Err("GPUs-per-node values must be even and >= 2 (2-socket nodes)".into());
        }
        if self.sizes.is_empty() || self.sizes.iter().any(|&s| s == 0) {
            return Err("block sizes must be non-empty and positive".into());
        }
        Ok(())
    }

    /// Flatten the axes into cells, in deterministic collective-major order.
    /// Sizes are sorted (and deduplicated) so per-regime winner lines read
    /// in ascending size order, which is what crossover detection assumes.
    pub fn cells(&self) -> Vec<ColCellSpec> {
        let mut sizes = self.sizes.clone();
        sizes.sort_unstable();
        sizes.dedup();
        let mut out =
            Vec::with_capacity(self.collectives.len() * self.nodes.len() * self.gpus_per_node.len() * sizes.len());
        for &collective in &self.collectives {
            for &nodes in &self.nodes {
                for &gpn in &self.gpus_per_node {
                    for &size in &sizes {
                        out.push(ColCellSpec { index: out.len(), collective, nodes, gpus_per_node: gpn, size });
                    }
                }
            }
        }
        out
    }
}

/// Full collective sweep configuration: the grid plus run controls.
#[derive(Clone, Debug)]
pub struct CollectiveConfig {
    pub grid: CollectiveGrid,
    /// Base seed; each cell derives its own deterministic sub-seed (fixes
    /// alltoallv's irregular counts).
    pub seed: u64,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Run the discrete-event simulator on the lowered schedules next to
    /// the composed models.
    pub sim: bool,
    /// Machine preset evaluated at every grid point (a
    /// [`machines::parse`] registry name; nodes and GPUs come from the
    /// grid axes).
    pub machine: String,
    /// Branch-and-bound pruning: skip simulating algorithms whose
    /// [`ColBoundModel`] lower bound exceeds the cell's best simulated
    /// time. Winner-preserving (model times are always computed; the
    /// simulated winner's bound can never exceed its own time). Default
    /// off.
    pub prune: bool,
    /// Adaptive refinement depth over the joint (nodes × size) lattice:
    /// 0 = exhaustive (default); `d > 0` starts on every `2^d`-th point of
    /// both axes and subdivides only where model winners disagree.
    pub refine: usize,
}

impl Default for CollectiveConfig {
    fn default() -> CollectiveConfig {
        CollectiveConfig {
            grid: CollectiveGrid::default(),
            seed: 42,
            threads: 0,
            sim: true,
            machine: "lassen".into(),
            prune: false,
            refine: 0,
        }
    }
}

/// One evaluated (cell × algorithm) pair.
#[derive(Clone, Debug)]
pub struct CollectiveCell {
    /// Index of the owning grid cell (groups the algorithms of one cell).
    pub index: usize,
    pub collective: Collective,
    pub algorithm: CollectiveAlgorithm,
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub size: usize,
    /// Composed Table 6 model prediction [s].
    pub model_s: f64,
    /// Discrete-event simulated time of the lowered schedule [s] (None
    /// when `sim` is off).
    pub sim_s: Option<f64>,
    /// Barrier-separated stages of the lowering.
    pub stages: usize,
    /// Inter-node messages the lowering issues across all stages.
    pub internode_msgs: usize,
    /// Inter-node bytes the lowering ships across all stages.
    pub internode_bytes: usize,
    /// True when branch-and-bound pruning skipped this algorithm's
    /// simulation (`sim_s` is then None even though `sim` was on).
    pub sim_pruned: bool,
}

/// The collective sweep outcome: per-cell results plus the derived report.
#[derive(Clone, Debug)]
pub struct CollectiveResult {
    pub config: CollectiveConfig,
    pub cells: Vec<CollectiveCell>,
    pub report: CollectiveReport,
    /// Threads the pool actually used.
    pub threads_used: usize,
    /// Wall-clock seconds for the evaluation (excluded from emitter output
    /// so seeded runs stay byte-identical).
    pub elapsed_s: f64,
}

/// Run the collective sweep: validate, fan out, aggregate, analyze.
pub fn run_collective(config: &CollectiveConfig) -> Result<CollectiveResult, String> {
    config.grid.validate()?;
    let (arch, params) = machines::parse(&config.machine, 1)?;
    let compiled_params = params.compile();
    let cells = config.grid.cells();
    let t0 = Instant::now();
    let threads = effective_threads(config.threads, cells.len());

    let cells_out: Vec<CollectiveCell> = if config.refine > 0 {
        run_col_refined(config, &arch, &params, &compiled_params, &cells, threads)
    } else {
        let results = pool::map_with(cells.len(), threads, sim::Scratch::new, |scratch, i| {
            eval_cell(config, &arch, &params, &compiled_params, &cells[i], scratch)
        });
        results.into_iter().flatten().collect()
    };
    let report = analyze(&cells_out);
    Ok(CollectiveResult {
        config: config.clone(),
        cells: cells_out,
        report,
        threads_used: threads,
        elapsed_s: t0.elapsed().as_secs_f64(),
    })
}

/// Adaptive 2-D refinement over the collective grid: each (collective, gpn)
/// pair is one (nodes × size) plane of the shared rectangle-subdivision
/// driver ([`refine_2d`]). Evaluated cells keep their exhaustive-grid
/// indices (hence their alltoallv seeds), so coinciding cells are
/// bit-identical to the full sweep; skipped cells are simply absent.
fn run_col_refined(
    config: &CollectiveConfig,
    arch: &Machine,
    params: &MachineParams,
    compiled_params: &CompiledParams,
    cells: &[ColCellSpec],
    threads: usize,
) -> Vec<CollectiveCell> {
    let grid = &config.grid;
    let mut sizes = grid.sizes.clone();
    sizes.sort_unstable();
    sizes.dedup();
    let n_sizes = sizes.len();
    let (n_nodes, n_gpn) = (grid.nodes.len(), grid.gpus_per_node.len());
    // cells() iterates collectives -> nodes -> gpn -> sizes
    let row_stride = n_gpn * n_sizes;
    let mut planes = Vec::with_capacity(grid.collectives.len() * n_gpn);
    for ci in 0..grid.collectives.len() {
        for g in 0..n_gpn {
            let origin = ci * n_nodes * row_stride + g * n_sizes;
            planes.push(PlaneGeom { origin, rows: n_nodes, row_stride, cols: n_sizes });
        }
    }

    let mut slots: Vec<Option<Vec<CollectiveCell>>> = vec![None; cells.len()];
    refine_2d(
        &planes,
        config.refine,
        &mut slots,
        |slots, wave| {
            let eff = effective_threads(threads, wave.len());
            let results = pool::map_with(wave.len(), eff, sim::Scratch::new, |scratch, i| {
                eval_cell(config, arch, params, compiled_params, &cells[wave[i]], scratch)
            });
            for (&i, group) in wave.iter().zip(results) {
                slots[i] = Some(group);
            }
        },
        |slots, i| {
            let group = slots[i].as_ref().expect("evaluated");
            // first-minimal-wins, matching report::analyze exactly
            group
                .iter()
                .min_by(|a, b| a.model_s.partial_cmp(&b.model_s).unwrap())
                .expect("non-empty")
                .algorithm
                .label()
        },
    );
    slots.into_iter().flatten().flatten().collect()
}

/// Evaluate one grid cell: synthesize the direct pattern once, then lower
/// and model every algorithm against it, and simulate the survivors.
/// Without `prune`, every algorithm simulates (legacy behavior). With it,
/// the [`ColBoundModel`] seeds the search at the least upper bound, then
/// visits the rest in ascending-lower-bound order, skipping any algorithm
/// whose sound lower bound exceeds the best simulated time so far. Model
/// times are computed for all algorithms regardless, and results come back
/// in configuration order.
fn eval_cell(
    cfg: &CollectiveConfig,
    arch: &Machine,
    params: &MachineParams,
    compiled_params: &CompiledParams,
    cell: &ColCellSpec,
    scratch: &mut sim::Scratch,
) -> Vec<CollectiveCell> {
    let machine = machines::with_shape(arch, cell.nodes, cell.gpus_per_node);
    let spec = CollectiveSpec::new(cell.collective, cell.size, cell_seed(cfg.seed, cell.index));
    let direct = spec.materialize(&machine);
    let ppn = machine.gpus_per_node();

    let algorithms = &cfg.grid.algorithms;
    let n = algorithms.len();
    let lowerings: Vec<_> = algorithms.iter().map(|&a| lower(cell.collective, a, &machine, &direct)).collect();
    let model_s: Vec<f64> = lowerings.iter().map(|l| model::algorithm_time(&machine, params, l)).collect();
    let mut sim_s: Vec<Option<f64>> = vec![None; n];
    let mut pruned = vec![false; n];

    if cfg.sim {
        let run = |idx: usize, scratch: &mut sim::Scratch| {
            let schedule = sim_schedule(&machine, &lowerings[idx]);
            scratch.run_total(&machine, compiled_params, &schedule, ppn)
        };
        if cfg.prune {
            let bm = ColBoundModel::new(&machine, params);
            let bounds: Vec<_> = lowerings.iter().map(|l| bm.bounds(l)).collect();
            // seed: least upper bound (ties break to configuration order)
            let seed = (0..n)
                .min_by(|&a, &b| bounds[a].upper.total_cmp(&bounds[b].upper).then(a.cmp(&b)))
                .expect("non-empty algorithm list");
            let mut best = run(seed, scratch);
            sim_s[seed] = Some(best);
            let mut order: Vec<usize> = (0..n).filter(|&i| i != seed).collect();
            order.sort_by(|&a, &b| bounds[a].lower.total_cmp(&bounds[b].lower).then(a.cmp(&b)));
            for idx in order {
                if bounds[idx].lower > best {
                    pruned[idx] = true;
                    continue;
                }
                let t = run(idx, scratch);
                if t < best {
                    best = t;
                }
                sim_s[idx] = Some(t);
            }
        } else {
            for idx in 0..n {
                sim_s[idx] = Some(run(idx, scratch));
            }
        }
    }

    let mut out = Vec::with_capacity(n);
    for (idx, &algorithm) in algorithms.iter().enumerate() {
        out.push(CollectiveCell {
            index: cell.index,
            collective: cell.collective,
            algorithm,
            nodes: cell.nodes,
            gpus_per_node: cell.gpus_per_node,
            size: cell.size,
            model_s: model_s[idx],
            sim_s: sim_s[idx],
            stages: lowerings[idx].stages.len(),
            internode_msgs: lowerings[idx].internode_msgs(&machine),
            internode_bytes: lowerings[idx].internode_bytes(&machine),
            sim_pruned: pruned[idx],
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_config(threads: usize) -> CollectiveConfig {
        CollectiveConfig {
            grid: CollectiveGrid {
                collectives: vec![Collective::Alltoall, Collective::Allgather],
                algorithms: CollectiveAlgorithm::ALL.to_vec(),
                nodes: vec![2, 3],
                gpus_per_node: vec![4],
                sizes: vec![512, 4096],
            },
            seed: 11,
            threads,
            sim: true,
            machine: "lassen".into(),
            prune: false,
            refine: 0,
        }
    }

    fn cmp_cells(a: &[CollectiveCell], b: &[CollectiveCell]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!((x.index, x.collective, x.algorithm), (y.index, y.collective, y.algorithm));
            assert_eq!(x.model_s.to_bits(), y.model_s.to_bits(), "{} {} model", x.collective, x.algorithm);
            assert_eq!(x.sim_s.map(f64::to_bits), y.sim_s.map(f64::to_bits), "{} {} sim", x.collective, x.algorithm);
        }
    }

    #[test]
    fn results_cover_grid_times_algorithms() {
        let cfg = small_config(2);
        let r = run_collective(&cfg).unwrap();
        assert_eq!(r.cells.len(), cfg.grid.cells().len() * cfg.grid.algorithms.len());
        assert!(r.cells.iter().all(|c| c.model_s.is_finite() && c.model_s > 0.0));
        assert!(r.cells.iter().all(|c| c.sim_s.is_some_and(|t| t.is_finite() && t > 0.0)));
        for w in r.cells.windows(2) {
            assert!(w[0].index <= w[1].index, "cells must come back in grid order");
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let r1 = run_collective(&small_config(1)).unwrap();
        let r4 = run_collective(&small_config(4)).unwrap();
        cmp_cells(&r1.cells, &r4.cells);
    }

    #[test]
    fn same_seed_same_bits_different_seed_differs() {
        let r1 = run_collective(&small_config(2)).unwrap();
        let r2 = run_collective(&small_config(2)).unwrap();
        cmp_cells(&r1.cells, &r2.cells);
        let mut cfg = small_config(2);
        cfg.grid.collectives = vec![Collective::Alltoallv];
        let a = run_collective(&cfg).unwrap();
        cfg.seed = 12;
        let b = run_collective(&cfg).unwrap();
        // alltoallv's irregular counts must move with the seed
        assert!(
            a.cells.iter().zip(&b.cells).any(|(x, y)| x.model_s.to_bits() != y.model_s.to_bits()),
            "seed must drive the alltoallv synthesis"
        );
    }

    #[test]
    fn model_only_skips_sim() {
        let mut cfg = small_config(2);
        cfg.sim = false;
        let r = run_collective(&cfg).unwrap();
        assert!(r.cells.iter().all(|c| c.sim_s.is_none()));
    }

    #[test]
    fn locality_never_issues_more_internode_msgs() {
        let cfg = small_config(1);
        let r = run_collective(&cfg).unwrap();
        let mut i = 0;
        while i < r.cells.len() {
            let mut j = i + 1;
            while j < r.cells.len() && r.cells[j].index == r.cells[i].index {
                j += 1;
            }
            let group = &r.cells[i..j];
            let of = |alg: CollectiveAlgorithm| group.iter().find(|c| c.algorithm == alg).unwrap();
            assert!(
                of(CollectiveAlgorithm::Locality).internode_msgs <= of(CollectiveAlgorithm::Standard).internode_msgs
            );
            i = j;
        }
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = small_config(1);
        cfg.grid.algorithms.clear();
        assert!(run_collective(&cfg).is_err());
        let mut cfg = small_config(1);
        cfg.grid.nodes = vec![1];
        assert!(run_collective(&cfg).is_err());
        let mut cfg = small_config(1);
        cfg.grid.gpus_per_node = vec![3];
        assert!(run_collective(&cfg).is_err());
        let mut cfg = small_config(1);
        cfg.machine = "bogus".into();
        assert!(run_collective(&cfg).is_err());
    }

    #[test]
    fn tiny_grid_is_small_and_valid() {
        let g = CollectiveGrid::tiny();
        g.validate().unwrap();
        assert!(g.cells().len() <= 16);
    }

    #[test]
    fn cells_sort_sizes_and_index_contiguously() {
        let g = CollectiveGrid {
            collectives: vec![Collective::Alltoall],
            algorithms: vec![CollectiveAlgorithm::Standard],
            nodes: vec![2],
            gpus_per_node: vec![4],
            sizes: vec![4096, 512, 4096],
        };
        let cells = g.cells();
        assert_eq!(cells.len(), 2);
        assert_eq!((cells[0].size, cells[1].size), (512, 4096));
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    /// Pruning-friendly grid: high node counts at extreme sizes keep the
    /// losing algorithms' bounds far from the winner's.
    fn prunable_config(threads: usize) -> CollectiveConfig {
        CollectiveConfig {
            grid: CollectiveGrid {
                collectives: Collective::ALL.to_vec(),
                algorithms: CollectiveAlgorithm::ALL.to_vec(),
                nodes: vec![8, 16],
                gpus_per_node: vec![4],
                sizes: vec![512, 1 << 11, 1 << 17],
            },
            seed: 7,
            threads,
            sim: true,
            ..Default::default()
        }
    }

    #[test]
    fn prune_preserves_everything_but_skipped_sims() {
        let full = run_collective(&prunable_config(2)).unwrap();
        let mut cfg = prunable_config(2);
        cfg.prune = true;
        let pruned = run_collective(&cfg).unwrap();
        assert_eq!(full.cells.len(), pruned.cells.len());
        let mut skipped = 0;
        for (a, b) in full.cells.iter().zip(&pruned.cells) {
            assert_eq!((a.index, a.collective, a.algorithm), (b.index, b.collective, b.algorithm));
            // model times (and hence winners/crossovers/regimes) are untouched
            assert_eq!(a.model_s.to_bits(), b.model_s.to_bits(), "{} {} model", a.collective, a.algorithm);
            if b.sim_pruned {
                skipped += 1;
                assert!(b.sim_s.is_none(), "{} {} pruned but simulated", b.collective, b.algorithm);
            } else {
                // surviving sims are bit-identical to the full run
                assert_eq!(
                    a.sim_s.map(f64::to_bits),
                    b.sim_s.map(f64::to_bits),
                    "{} {} sim",
                    a.collective,
                    a.algorithm
                );
            }
        }
        assert!(skipped > 0, "this grid must actually prune something");
        // soundness end-to-end: no pruned algorithm could have won a cell's sim
        let per = cfg.grid.algorithms.len();
        for group in pruned.cells.chunks(per) {
            let best = group.iter().filter_map(|c| c.sim_s).fold(f64::INFINITY, f64::min);
            let full_group = &full.cells[group[0].index * per..group[0].index * per + per];
            for (c, f) in group.iter().zip(full_group) {
                if c.sim_pruned {
                    assert!(f.sim_s.unwrap() >= best, "{} {} pruned yet beat the incumbent", c.collective, c.algorithm);
                }
            }
        }
        // winner/crossover/regime reports are identical (the `pruned`
        // count is the only winner field allowed to move)
        let key = |w: &crate::collective::CollectiveWinner| (w.size, w.winner, w.sim_winner, w.model_s.to_bits());
        assert_eq!(
            full.report.winners.iter().map(key).collect::<Vec<_>>(),
            pruned.report.winners.iter().map(key).collect::<Vec<_>>()
        );
        assert_eq!(full.report.crossovers, pruned.report.crossovers);
        assert_eq!(full.report.regimes, pruned.report.regimes);
        // accounting matches the per-cell flags
        assert_eq!(pruned.report.prune.pruned, skipped);
        assert_eq!(pruned.report.prune.cells, full.report.winners.len());
        assert_eq!(pruned.report.prune.sim_evals + skipped, full.report.prune.sim_evals);
        assert_eq!(full.report.prune.pruned, 0);
        // pruned runs stay deterministic and thread-invariant
        cfg.threads = 1;
        let pruned1 = run_collective(&cfg).unwrap();
        cmp_cells(&pruned.cells, &pruned1.cells);
    }

    #[test]
    fn prune_never_marks_without_flag() {
        let r = run_collective(&small_config(2)).unwrap();
        assert!(r.cells.iter().all(|c| !c.sim_pruned));
    }

    #[test]
    fn refined_cells_match_exhaustive_where_they_coincide() {
        // 3 node values x 5 sizes: depth 1 leaves interior points on both
        // axes for the subdivision to find. Standard vs locality has a
        // monotone winner boundary in (nodes, size), so rectangle tracing
        // resolves the full crossover set.
        let mut base = prunable_config(2);
        base.grid.algorithms = vec![CollectiveAlgorithm::Standard, CollectiveAlgorithm::Locality];
        base.grid.nodes = vec![2, 8, 32];
        base.grid.sizes = (9..=17).step_by(2).map(|e| 1usize << e).collect();
        let exhaustive = run_collective(&base).unwrap();
        let mut cfg = base;
        cfg.refine = 1;
        cfg.prune = true;
        let refined = run_collective(&cfg).unwrap();
        assert!(refined.cells.len() <= exhaustive.cells.len());
        assert!(!refined.cells.is_empty());
        let per = cfg.grid.algorithms.len();
        // plane corners are always present
        assert_eq!(refined.cells[0].index, 0);
        assert_eq!(refined.cells.last().unwrap().index, exhaustive.cells.last().unwrap().index);
        for group in refined.cells.chunks(per) {
            let full_group = &exhaustive.cells[group[0].index * per..group[0].index * per + per];
            for (r, f) in group.iter().zip(full_group) {
                assert_eq!(r.algorithm, f.algorithm);
                assert_eq!(r.model_s.to_bits(), f.model_s.to_bits(), "{} {} model", r.collective, r.algorithm);
                if !r.sim_pruned {
                    assert_eq!(
                        r.sim_s.map(f64::to_bits),
                        f.sim_s.map(f64::to_bits),
                        "{} {} sim",
                        r.collective,
                        r.algorithm
                    );
                }
            }
        }
        // the coarse pass plus subdivisions still finds every model winner
        // transition the exhaustive report sees (crossover sizes coincide)
        assert_eq!(exhaustive.report.crossovers, refined.report.crossovers, "refinement must resolve the boundary");
        // thread invariance holds with wave-granular work units too
        cfg.threads = 1;
        let refined1 = run_collective(&cfg).unwrap();
        cmp_cells(&refined.cells, &refined1.cells);
    }

    #[test]
    fn refine_depth_larger_than_axes_still_covers_corners() {
        let mut cfg = small_config(1);
        cfg.refine = 30; // stride clamps; lattice degenerates to endpoints
        let r = run_collective(&cfg).unwrap();
        assert!(!r.cells.is_empty());
        let idx: std::collections::BTreeSet<usize> = r.cells.iter().map(|c| c.index).collect();
        // both axes have 2 points, so every cell is a plane corner
        assert_eq!(idx.len(), cfg.grid.cells().len());
    }

    #[test]
    fn machine_preset_changes_model_times() {
        let mut base = small_config(1);
        base.sim = false;
        let lassen = run_collective(&base).unwrap();
        let mut frontier = small_config(1);
        frontier.sim = false;
        frontier.machine = "frontier-like".into();
        let frontier = run_collective(&frontier).unwrap();
        assert_eq!(lassen.cells.len(), frontier.cells.len());
        assert!(
            lassen.cells.iter().zip(&frontier.cells).any(|(a, b)| a.model_s.to_bits() != b.model_s.to_bits()),
            "the machine preset must reach the composed models"
        );
    }
}
