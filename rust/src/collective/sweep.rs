//! The collective sweep engine: fan the (collective × nodes × gpn × size)
//! grid out over the in-tree worker pool, evaluate every algorithm variant
//! per point through the composed Table 6 models and (optionally) the
//! discrete-event simulator, and collect results in a deterministic order.
//!
//! Same determinism contract as [`crate::sweep`]: given the same
//! [`CollectiveConfig`] (including `seed`), two runs produce byte-identical
//! emitter output regardless of thread count — cells are seeded by index
//! and results land in pre-sized per-cell slots in grid order.

use super::report::{analyze, CollectiveReport};
use super::{lower, model, sim_schedule, Collective, CollectiveAlgorithm, CollectiveSpec};
use crate::params::{CompiledParams, MachineParams};
use crate::sim;
use crate::topology::{machines, Machine};
use crate::util::pool;
use crate::util::pool::effective_threads;
use crate::util::rng::index_seed as cell_seed;
use std::time::Instant;

/// The collective grid: every combination of the axes below is one cell,
/// and every cell is evaluated for every selected algorithm.
#[derive(Clone, Debug, PartialEq)]
pub struct CollectiveGrid {
    /// Collectives to sweep.
    pub collectives: Vec<Collective>,
    /// Algorithm variants evaluated at every grid point.
    pub algorithms: Vec<CollectiveAlgorithm>,
    /// Node counts (every process participates — no extra sender node).
    pub nodes: Vec<usize>,
    /// GPUs per node (even: the preset node keeps its 2 sockets).
    pub gpus_per_node: Vec<usize>,
    /// Per-pair block sizes in bytes (alltoallv jitters around them).
    pub sizes: Vec<usize>,
}

impl Default for CollectiveGrid {
    fn default() -> CollectiveGrid {
        CollectiveGrid {
            collectives: Collective::ALL.to_vec(),
            algorithms: CollectiveAlgorithm::ALL.to_vec(),
            nodes: vec![2, 8, 32],
            gpus_per_node: vec![4],
            sizes: (9..=19).step_by(2).map(|e| 1usize << e).collect(),
        }
    }
}

/// One unit of collective sweep work: a fully-specified grid point (all
/// algorithms are evaluated inside the cell so the direct pattern is
/// synthesized once).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ColCellSpec {
    /// Position in [`CollectiveGrid::cells`] — drives the per-cell seed and
    /// the deterministic output order.
    pub index: usize,
    pub collective: Collective,
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub size: usize,
}

impl CollectiveGrid {
    /// A sub-second grid for CI smoke tests that still exercises every
    /// collective and algorithm on both sides of the small/large band.
    pub fn tiny() -> CollectiveGrid {
        CollectiveGrid {
            collectives: Collective::ALL.to_vec(),
            algorithms: CollectiveAlgorithm::ALL.to_vec(),
            nodes: vec![2, 4],
            gpus_per_node: vec![4],
            sizes: vec![512, 1 << 14],
        }
    }

    /// Check axis sanity; returns a user-facing message on failure.
    pub fn validate(&self) -> Result<(), String> {
        if self.collectives.is_empty() {
            return Err("no collectives selected".into());
        }
        if self.algorithms.is_empty() {
            return Err("no collective algorithms selected".into());
        }
        if self.nodes.is_empty() || self.nodes.iter().any(|&n| n < 2) {
            return Err("collective node counts must be non-empty and >= 2".into());
        }
        if self.gpus_per_node.is_empty() || self.gpus_per_node.iter().any(|&g| g < 2 || g % 2 != 0) {
            return Err("GPUs-per-node values must be even and >= 2 (2-socket nodes)".into());
        }
        if self.sizes.is_empty() || self.sizes.iter().any(|&s| s == 0) {
            return Err("block sizes must be non-empty and positive".into());
        }
        Ok(())
    }

    /// Flatten the axes into cells, in deterministic collective-major order.
    /// Sizes are sorted (and deduplicated) so per-regime winner lines read
    /// in ascending size order, which is what crossover detection assumes.
    pub fn cells(&self) -> Vec<ColCellSpec> {
        let mut sizes = self.sizes.clone();
        sizes.sort_unstable();
        sizes.dedup();
        let mut out =
            Vec::with_capacity(self.collectives.len() * self.nodes.len() * self.gpus_per_node.len() * sizes.len());
        for &collective in &self.collectives {
            for &nodes in &self.nodes {
                for &gpn in &self.gpus_per_node {
                    for &size in &sizes {
                        out.push(ColCellSpec { index: out.len(), collective, nodes, gpus_per_node: gpn, size });
                    }
                }
            }
        }
        out
    }
}

/// Full collective sweep configuration: the grid plus run controls.
#[derive(Clone, Debug)]
pub struct CollectiveConfig {
    pub grid: CollectiveGrid,
    /// Base seed; each cell derives its own deterministic sub-seed (fixes
    /// alltoallv's irregular counts).
    pub seed: u64,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Run the discrete-event simulator on the lowered schedules next to
    /// the composed models.
    pub sim: bool,
    /// Machine preset evaluated at every grid point (a
    /// [`machines::parse`] registry name; nodes and GPUs come from the
    /// grid axes).
    pub machine: String,
}

impl Default for CollectiveConfig {
    fn default() -> CollectiveConfig {
        CollectiveConfig { grid: CollectiveGrid::default(), seed: 42, threads: 0, sim: true, machine: "lassen".into() }
    }
}

/// One evaluated (cell × algorithm) pair.
#[derive(Clone, Debug)]
pub struct CollectiveCell {
    /// Index of the owning grid cell (groups the algorithms of one cell).
    pub index: usize,
    pub collective: Collective,
    pub algorithm: CollectiveAlgorithm,
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub size: usize,
    /// Composed Table 6 model prediction [s].
    pub model_s: f64,
    /// Discrete-event simulated time of the lowered schedule [s] (None
    /// when `sim` is off).
    pub sim_s: Option<f64>,
    /// Barrier-separated stages of the lowering.
    pub stages: usize,
    /// Inter-node messages the lowering issues across all stages.
    pub internode_msgs: usize,
    /// Inter-node bytes the lowering ships across all stages.
    pub internode_bytes: usize,
}

/// The collective sweep outcome: per-cell results plus the derived report.
#[derive(Clone, Debug)]
pub struct CollectiveResult {
    pub config: CollectiveConfig,
    pub cells: Vec<CollectiveCell>,
    pub report: CollectiveReport,
    /// Threads the pool actually used.
    pub threads_used: usize,
    /// Wall-clock seconds for the evaluation (excluded from emitter output
    /// so seeded runs stay byte-identical).
    pub elapsed_s: f64,
}

/// Run the collective sweep: validate, fan out, aggregate, analyze.
pub fn run_collective(config: &CollectiveConfig) -> Result<CollectiveResult, String> {
    config.grid.validate()?;
    let (arch, params) = machines::parse(&config.machine, 1)?;
    let compiled_params = params.compile();
    let cells = config.grid.cells();
    let t0 = Instant::now();
    let threads = effective_threads(config.threads, cells.len());

    let results = pool::map_with(cells.len(), threads, sim::Scratch::new, |scratch, i| {
        eval_cell(config, &arch, &params, &compiled_params, &cells[i], scratch)
    });
    let cells_out: Vec<CollectiveCell> = results.into_iter().flatten().collect();
    let report = analyze(&cells_out);
    Ok(CollectiveResult {
        config: config.clone(),
        cells: cells_out,
        report,
        threads_used: threads,
        elapsed_s: t0.elapsed().as_secs_f64(),
    })
}

/// Evaluate one grid cell: synthesize the direct pattern once, then lower,
/// model and (optionally) simulate every algorithm against it.
fn eval_cell(
    cfg: &CollectiveConfig,
    arch: &Machine,
    params: &MachineParams,
    compiled_params: &CompiledParams,
    cell: &ColCellSpec,
    scratch: &mut sim::Scratch,
) -> Vec<CollectiveCell> {
    let machine = machines::with_shape(arch, cell.nodes, cell.gpus_per_node);
    let spec = CollectiveSpec::new(cell.collective, cell.size, cell_seed(cfg.seed, cell.index));
    let direct = spec.materialize(&machine);
    let ppn = machine.gpus_per_node();

    let mut out = Vec::with_capacity(cfg.grid.algorithms.len());
    for &algorithm in &cfg.grid.algorithms {
        let lowering = lower(cell.collective, algorithm, &machine, &direct);
        let model_s = model::algorithm_time(&machine, params, &lowering);
        let sim_s = cfg.sim.then(|| {
            let schedule = sim_schedule(&machine, &lowering);
            scratch.run_total(&machine, compiled_params, &schedule, ppn)
        });
        out.push(CollectiveCell {
            index: cell.index,
            collective: cell.collective,
            algorithm,
            nodes: cell.nodes,
            gpus_per_node: cell.gpus_per_node,
            size: cell.size,
            model_s,
            sim_s,
            stages: lowering.stages.len(),
            internode_msgs: lowering.internode_msgs(&machine),
            internode_bytes: lowering.internode_bytes(&machine),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_config(threads: usize) -> CollectiveConfig {
        CollectiveConfig {
            grid: CollectiveGrid {
                collectives: vec![Collective::Alltoall, Collective::Allgather],
                algorithms: CollectiveAlgorithm::ALL.to_vec(),
                nodes: vec![2, 3],
                gpus_per_node: vec![4],
                sizes: vec![512, 4096],
            },
            seed: 11,
            threads,
            sim: true,
            machine: "lassen".into(),
        }
    }

    fn cmp_cells(a: &[CollectiveCell], b: &[CollectiveCell]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!((x.index, x.collective, x.algorithm), (y.index, y.collective, y.algorithm));
            assert_eq!(x.model_s.to_bits(), y.model_s.to_bits(), "{} {} model", x.collective, x.algorithm);
            assert_eq!(x.sim_s.map(f64::to_bits), y.sim_s.map(f64::to_bits), "{} {} sim", x.collective, x.algorithm);
        }
    }

    #[test]
    fn results_cover_grid_times_algorithms() {
        let cfg = small_config(2);
        let r = run_collective(&cfg).unwrap();
        assert_eq!(r.cells.len(), cfg.grid.cells().len() * cfg.grid.algorithms.len());
        assert!(r.cells.iter().all(|c| c.model_s.is_finite() && c.model_s > 0.0));
        assert!(r.cells.iter().all(|c| c.sim_s.is_some_and(|t| t.is_finite() && t > 0.0)));
        for w in r.cells.windows(2) {
            assert!(w[0].index <= w[1].index, "cells must come back in grid order");
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let r1 = run_collective(&small_config(1)).unwrap();
        let r4 = run_collective(&small_config(4)).unwrap();
        cmp_cells(&r1.cells, &r4.cells);
    }

    #[test]
    fn same_seed_same_bits_different_seed_differs() {
        let r1 = run_collective(&small_config(2)).unwrap();
        let r2 = run_collective(&small_config(2)).unwrap();
        cmp_cells(&r1.cells, &r2.cells);
        let mut cfg = small_config(2);
        cfg.grid.collectives = vec![Collective::Alltoallv];
        let a = run_collective(&cfg).unwrap();
        cfg.seed = 12;
        let b = run_collective(&cfg).unwrap();
        // alltoallv's irregular counts must move with the seed
        assert!(
            a.cells.iter().zip(&b.cells).any(|(x, y)| x.model_s.to_bits() != y.model_s.to_bits()),
            "seed must drive the alltoallv synthesis"
        );
    }

    #[test]
    fn model_only_skips_sim() {
        let mut cfg = small_config(2);
        cfg.sim = false;
        let r = run_collective(&cfg).unwrap();
        assert!(r.cells.iter().all(|c| c.sim_s.is_none()));
    }

    #[test]
    fn locality_never_issues_more_internode_msgs() {
        let cfg = small_config(1);
        let r = run_collective(&cfg).unwrap();
        let mut i = 0;
        while i < r.cells.len() {
            let mut j = i + 1;
            while j < r.cells.len() && r.cells[j].index == r.cells[i].index {
                j += 1;
            }
            let group = &r.cells[i..j];
            let of = |alg: CollectiveAlgorithm| group.iter().find(|c| c.algorithm == alg).unwrap();
            assert!(
                of(CollectiveAlgorithm::Locality).internode_msgs <= of(CollectiveAlgorithm::Standard).internode_msgs
            );
            i = j;
        }
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = small_config(1);
        cfg.grid.algorithms.clear();
        assert!(run_collective(&cfg).is_err());
        let mut cfg = small_config(1);
        cfg.grid.nodes = vec![1];
        assert!(run_collective(&cfg).is_err());
        let mut cfg = small_config(1);
        cfg.grid.gpus_per_node = vec![3];
        assert!(run_collective(&cfg).is_err());
        let mut cfg = small_config(1);
        cfg.machine = "bogus".into();
        assert!(run_collective(&cfg).is_err());
    }

    #[test]
    fn tiny_grid_is_small_and_valid() {
        let g = CollectiveGrid::tiny();
        g.validate().unwrap();
        assert!(g.cells().len() <= 16);
    }

    #[test]
    fn cells_sort_sizes_and_index_contiguously() {
        let g = CollectiveGrid {
            collectives: vec![Collective::Alltoall],
            algorithms: vec![CollectiveAlgorithm::Standard],
            nodes: vec![2],
            gpus_per_node: vec![4],
            sizes: vec![4096, 512, 4096],
        };
        let cells = g.cells();
        assert_eq!(cells.len(), 2);
        assert_eq!((cells[0].size, cells[1].size), (512, 4096));
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    #[test]
    fn machine_preset_changes_model_times() {
        let mut base = small_config(1);
        base.sim = false;
        let lassen = run_collective(&base).unwrap();
        let mut frontier = small_config(1);
        frontier.sim = false;
        frontier.machine = "frontier-like".into();
        let frontier = run_collective(&frontier).unwrap();
        assert_eq!(lassen.cells.len(), frontier.cells.len());
        assert!(
            lassen.cells.iter().zip(&frontier.cells).any(|(a, b)| a.model_s.to_bits() != b.model_s.to_bits()),
            "the machine preset must reach the composed models"
        );
    }
}
