//! Lowering a collective into per-stage point-to-point patterns.
//!
//! Every [`CollectiveAlgorithm`] turns the *direct* pattern (one logical
//! message per ordered process pair, [`crate::collective::CollectiveSpec::materialize`])
//! into an ordered list of [`Stage`]s, each a plain
//! [`crate::pattern::CommPattern`]:
//!
//! - **standard** — one stage, the direct pattern verbatim;
//! - **pairwise** — round `r` carries the messages whose destination node
//!   is `r` hops ahead of the source node (round 0 is the on-node
//!   exchange); rounds are barriers;
//! - **locality** — the `MPIX_Alltoall` three-phase shape: each ordered
//!   node pair `(sn, dn)` is assigned an [`owner`] process on `sn` and a
//!   [`recv_owner`] on `dn`; stage 1 gathers each sender's payloads to the
//!   owners (and delivers on-node messages directly), stage 2 ships **one
//!   aggregated message per ordered node pair**, stage 3 redistributes to
//!   final destinations. Duplicate payloads (`dup_group`, e.g. allgather)
//!   cross the network once per destination node — the gather and exchange
//!   stages carry deduplicated bytes, the redistribute stage restores the
//!   full per-destination payloads.
//!
//! Stage patterns are aggregated through ordered maps, so the lowering is a
//! pure function of the message *set* — shuffling the direct pattern's
//! message order cannot change any stage.

use super::{Collective, CollectiveAlgorithm};
use crate::comm::{build_schedule, CopyKind, CopyOp, Loc, Phase, Schedule, Strategy, StrategyKind, Transport, Xfer};
use crate::pattern::{CommPattern, Msg};
use crate::topology::{GpuId, Machine, NodeId};
use std::collections::{BTreeMap, BTreeSet};

/// One barrier-separated stage of a lowered collective.
#[derive(Clone, Debug, PartialEq)]
pub struct Stage {
    pub label: &'static str,
    pub pattern: CommPattern,
}

/// A collective lowered to stages.
#[derive(Clone, Debug, PartialEq)]
pub struct Lowering {
    pub collective: Collective,
    pub algorithm: CollectiveAlgorithm,
    pub stages: Vec<Stage>,
}

impl Lowering {
    /// Total inter-node messages across all stages (the quantity the
    /// locality algorithm minimizes).
    pub fn internode_msgs(&self, machine: &Machine) -> usize {
        self.stages.iter().map(|s| s.pattern.internode(machine).count()).sum()
    }

    /// Total inter-node bytes across all stages.
    pub fn internode_bytes(&self, machine: &Machine) -> usize {
        self.stages.iter().map(|s| s.pattern.internode(machine).map(|m| m.bytes).sum::<usize>()).sum()
    }
}

/// The process on node `sn` that aggregates and ships the `(sn, dn)`
/// node-pair payload: destination nodes are dealt round-robin over the
/// sender node's processes (the mpi-advance assignment).
pub fn owner(machine: &Machine, sn: NodeId, dn: NodeId) -> GpuId {
    GpuId(sn.0 * machine.gpus_per_node() + dn.0 % machine.gpus_per_node())
}

/// The process on node `dn` that receives the `(sn, dn)` node-pair payload
/// and redistributes it on-node.
pub fn recv_owner(machine: &Machine, sn: NodeId, dn: NodeId) -> GpuId {
    GpuId(dn.0 * machine.gpus_per_node() + sn.0 % machine.gpus_per_node())
}

/// Lower `direct` under `algorithm`. Empty stages are dropped.
pub fn lower(
    collective: Collective,
    algorithm: CollectiveAlgorithm,
    machine: &Machine,
    direct: &CommPattern,
) -> Lowering {
    let stages = match algorithm {
        CollectiveAlgorithm::Standard => {
            vec![Stage { label: "direct", pattern: direct.clone() }]
        }
        CollectiveAlgorithm::Pairwise => lower_pairwise(machine, direct),
        CollectiveAlgorithm::Locality => lower_locality(machine, direct),
    };
    Lowering { collective, algorithm, stages: stages.into_iter().filter(|s| !s.pattern.is_empty()).collect() }
}

fn lower_pairwise(machine: &Machine, direct: &CommPattern) -> Vec<Stage> {
    let n = machine.num_nodes;
    let mut rounds: BTreeMap<usize, Vec<Msg>> = BTreeMap::new();
    for m in &direct.msgs {
        let sn = machine.gpu_node(m.src).0;
        let dn = machine.gpu_node(m.dst).0;
        let r = (dn + n - sn) % n;
        rounds.entry(r).or_default().push(*m);
    }
    rounds
        .into_iter()
        .map(|(r, msgs)| Stage { label: if r == 0 { "local" } else { "round" }, pattern: CommPattern::new(msgs) })
        .collect()
}

fn lower_locality(machine: &Machine, direct: &CommPattern) -> Vec<Stage> {
    // Aggregated bytes per (src, dst) process pair for the on-node stages,
    // and per ordered node pair for the exchange stage.
    let mut gather: BTreeMap<(GpuId, GpuId), usize> = BTreeMap::new();
    let mut exchange: BTreeMap<(NodeId, NodeId), usize> = BTreeMap::new();
    let mut redist: BTreeMap<(GpuId, GpuId), usize> = BTreeMap::new();
    let mut seen: BTreeSet<(GpuId, u32, NodeId)> = BTreeSet::new();

    for m in &direct.msgs {
        let sn = machine.gpu_node(m.src);
        let dn = machine.gpu_node(m.dst);
        if sn == dn {
            // On-node messages are delivered directly in the gather stage.
            *gather.entry((m.src, m.dst)).or_default() += m.bytes;
            continue;
        }
        // Duplicate payloads cross the network once per destination node:
        // only the first (src, dup_group, dst-node) occurrence is gathered
        // and exchanged; every occurrence is redistributed on arrival.
        let unique = m.dup_group == Msg::NO_DUP || seen.insert((m.src, m.dup_group, dn));
        if unique {
            let own = owner(machine, sn, dn);
            if m.src != own {
                *gather.entry((m.src, own)).or_default() += m.bytes;
            }
            *exchange.entry((sn, dn)).or_default() += m.bytes;
        }
        let ro = recv_owner(machine, sn, dn);
        if ro != m.dst {
            *redist.entry((ro, m.dst)).or_default() += m.bytes;
        }
    }

    let pairs = |map: BTreeMap<(GpuId, GpuId), usize>| {
        CommPattern::new(map.into_iter().map(|((src, dst), bytes)| Msg::new(src, dst, bytes)).collect())
    };
    let exchange = CommPattern::new(
        exchange
            .into_iter()
            .map(|((sn, dn), bytes)| Msg::new(owner(machine, sn, dn), recv_owner(machine, sn, dn), bytes))
            .collect(),
    );
    vec![
        Stage { label: "gather", pattern: pairs(gather) },
        Stage { label: "exchange", pattern: exchange },
        Stage { label: "redistribute", pattern: pairs(redist) },
    ]
}

/// Build the end-to-end simulator schedule for a lowered collective, on
/// staged transport. Standard and locality stages reuse the Standard
/// (staged) schedule generator verbatim — D2H, host↔host, H2D per stage.
/// Pairwise stages share one up-front D2H and one final H2D (the payload
/// is resident on the host across rounds), with one barrier phase per
/// round in between.
pub fn sim_schedule(machine: &Machine, lowering: &Lowering) -> Schedule {
    let staged = Strategy::new(StrategyKind::Standard, Transport::Staged).expect("standard staged");
    let mut phases: Vec<Phase> = Vec::new();
    match lowering.algorithm {
        CollectiveAlgorithm::Standard | CollectiveAlgorithm::Locality => {
            for stage in &lowering.stages {
                phases.extend(build_schedule(staged, machine, &stage.pattern).phases);
            }
        }
        CollectiveAlgorithm::Pairwise => {
            let mut out: BTreeMap<GpuId, usize> = BTreeMap::new();
            let mut inn: BTreeMap<GpuId, usize> = BTreeMap::new();
            for stage in &lowering.stages {
                for m in &stage.pattern.msgs {
                    *out.entry(m.src).or_default() += m.bytes;
                    *inn.entry(m.dst).or_default() += m.bytes;
                }
            }
            let mut d2h = Phase::new("d2h");
            for (&g, &bytes) in &out {
                let proc = machine.gpu_host_proc(g, 1);
                d2h.copies.push(CopyOp { gpu: g, proc, bytes, dir: CopyKind::D2H, nprocs: 1 });
            }
            phases.push(d2h);
            for stage in &lowering.stages {
                let mut p2p = Phase::new(stage.label);
                for m in &stage.pattern.msgs {
                    p2p.xfers.push(Xfer {
                        src: Loc::Host(machine.gpu_host_proc(m.src, 1)),
                        dst: Loc::Host(machine.gpu_host_proc(m.dst, 1)),
                        bytes: m.bytes,
                        tag: u32::MAX,
                    });
                }
                phases.push(p2p);
            }
            let mut h2d = Phase::new("h2d");
            for (&g, &bytes) in &inn {
                let proc = machine.gpu_host_proc(g, 1);
                h2d.copies.push(CopyOp { gpu: g, proc, bytes, dir: CopyKind::H2D, nprocs: 1 });
            }
            phases.push(h2d);
        }
    }
    Schedule {
        strategy_label: format!("{} {}", lowering.collective.label(), lowering.algorithm.label()),
        phases: phases.into_iter().filter(|p| !p.is_empty()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::CollectiveSpec;
    use crate::topology::machines::lassen;

    fn direct(c: Collective, nodes: usize, block: usize) -> (Machine, CommPattern) {
        let m = lassen(nodes);
        let p = CollectiveSpec::new(c, block, 42).materialize(&m);
        (m, p)
    }

    #[test]
    fn standard_is_identity() {
        let (m, p) = direct(Collective::Alltoall, 3, 512);
        let l = lower(Collective::Alltoall, CollectiveAlgorithm::Standard, &m, &p);
        assert_eq!(l.stages.len(), 1);
        assert_eq!(l.stages[0].pattern, p);
    }

    #[test]
    fn pairwise_rounds_partition_the_pattern() {
        let (m, p) = direct(Collective::Alltoallv, 4, 1024);
        let l = lower(Collective::Alltoallv, CollectiveAlgorithm::Pairwise, &m, &p);
        assert_eq!(l.stages.len(), 4, "local round + 3 exchange rounds");
        assert_eq!(l.stages[0].label, "local");
        let total: usize = l.stages.iter().map(|s| s.pattern.total_bytes()).sum();
        assert_eq!(total, p.total_bytes());
        let msgs: usize = l.stages.iter().map(|s| s.pattern.msgs.len()).sum();
        assert_eq!(msgs, p.msgs.len());
        // each round >= 1 has a single destination-node offset
        for s in &l.stages[1..] {
            let offs: BTreeSet<usize> = s
                .pattern
                .msgs
                .iter()
                .map(|x| (m.gpu_node(x.dst).0 + m.num_nodes - m.gpu_node(x.src).0) % m.num_nodes)
                .collect();
            assert_eq!(offs.len(), 1);
        }
    }

    #[test]
    fn locality_exchange_is_one_msg_per_node_pair() {
        let (m, p) = direct(Collective::Alltoallv, 4, 1024);
        let l = lower(Collective::Alltoallv, CollectiveAlgorithm::Locality, &m, &p);
        assert_eq!(l.stages.len(), 3);
        let exchange = &l.stages[1];
        assert_eq!(exchange.label, "exchange");
        assert_eq!(exchange.pattern.msgs.len(), m.num_nodes * (m.num_nodes - 1));
        // every exchange message is inter-node, between the assigned owners
        for x in &exchange.pattern.msgs {
            let (sn, dn) = (m.gpu_node(x.src), m.gpu_node(x.dst));
            assert_ne!(sn, dn);
            assert_eq!(x.src, owner(&m, sn, dn));
            assert_eq!(x.dst, recv_owner(&m, sn, dn));
        }
        // gather and redistribute never cross nodes
        assert_eq!(l.stages[0].pattern.internode(&m).count(), 0);
        assert_eq!(l.stages[2].pattern.internode(&m).count(), 0);
    }

    #[test]
    fn locality_exchange_conserves_internode_bytes() {
        let (m, p) = direct(Collective::Alltoallv, 4, 1024);
        let l = lower(Collective::Alltoallv, CollectiveAlgorithm::Locality, &m, &p);
        let direct_inter: usize = p.internode(&m).map(|x| x.bytes).sum();
        assert_eq!(l.internode_bytes(&m), direct_inter, "no duplicates: exchange ships everything once");
    }

    #[test]
    fn locality_dedups_allgather_exchange() {
        let (m, p) = direct(Collective::Allgather, 4, 1024);
        let l = lower(Collective::Allgather, CollectiveAlgorithm::Locality, &m, &p);
        let gpn = m.gpus_per_node();
        let direct_inter: usize = p.internode(&m).map(|x| x.bytes).sum();
        // one block per (source proc, destination node) crosses the network
        assert_eq!(l.internode_bytes(&m), direct_inter / gpn);
        // but the redistribute stage restores every duplicate on-node
        let kept: usize = p
            .internode(&m)
            .filter(|x| x.dst == recv_owner(&m, m.gpu_node(x.src), m.gpu_node(x.dst)))
            .map(|x| x.bytes)
            .sum();
        let redist_and_kept = l.stages[2].pattern.total_bytes() + kept;
        assert_eq!(redist_and_kept, direct_inter);
    }

    #[test]
    fn lowering_is_order_invariant() {
        let (m, p) = direct(Collective::Alltoallv, 3, 2048);
        let mut shuffled = p.clone();
        let mut rng = crate::util::rng::Rng::new(5);
        rng.shuffle(&mut shuffled.msgs);
        assert_ne!(p.msgs, shuffled.msgs, "shuffle changed enumeration order");
        for alg in CollectiveAlgorithm::ALL {
            let a = lower(Collective::Alltoallv, alg, &m, &p);
            let b = lower(Collective::Alltoallv, alg, &m, &shuffled);
            match alg {
                // standard preserves enumeration order by construction;
                // compare as multisets via sorted copies
                CollectiveAlgorithm::Standard | CollectiveAlgorithm::Pairwise => {
                    let sort = |l: &Lowering| {
                        l.stages
                            .iter()
                            .map(|s| {
                                let mut v: Vec<(usize, usize, usize)> =
                                    s.pattern.msgs.iter().map(|x| (x.src.0, x.dst.0, x.bytes)).collect();
                                v.sort_unstable();
                                v
                            })
                            .collect::<Vec<_>>()
                    };
                    assert_eq!(sort(&a), sort(&b), "{alg}");
                }
                CollectiveAlgorithm::Locality => assert_eq!(a, b, "locality lowering must be canonical"),
            }
        }
    }

    #[test]
    fn sim_schedules_have_expected_shape() {
        let (m, p) = direct(Collective::Alltoall, 3, 512);
        for alg in CollectiveAlgorithm::ALL {
            let l = lower(Collective::Alltoall, alg, &m, &p);
            let sched = sim_schedule(&m, &l);
            assert!(!sched.phases.is_empty());
            let total: usize = sched.phases.iter().flat_map(|ph| &ph.xfers).map(|x| x.bytes).sum();
            let lowered: usize = l.stages.iter().map(|s| s.pattern.total_bytes()).sum();
            assert_eq!(total, lowered, "{alg}: schedule must carry every lowered byte");
        }
        // pairwise: one d2h + 3 rounds + one h2d
        let l = lower(Collective::Alltoall, CollectiveAlgorithm::Pairwise, &m, &p);
        let sched = sim_schedule(&m, &l);
        assert_eq!(sched.phases.len(), 1 + 3 + 1);
        assert_eq!(sched.phases[0].label, "d2h");
        assert_eq!(sched.phases.last().unwrap().label, "h2d");
    }
}
