//! Collective sweep analysis: per-cell winning algorithms, winner
//! crossovers along the size axis, and per-band regime winners — the
//! collective twin of [`crate::sweep::report`], driving the headline
//! "locality-aware alltoallv wins the high-node-count small-message
//! regime" narrative.

use super::sweep::CollectiveCell;
use super::Collective;
use crate::sweep::{PruneSummary, SMALL_BAND_MAX};
use std::collections::BTreeMap;

/// The model-fastest algorithm of one collective grid cell.
#[derive(Clone, Debug, PartialEq)]
pub struct CollectiveWinner {
    pub collective: Collective,
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub size: usize,
    /// Label of the model-fastest algorithm.
    pub winner: &'static str,
    pub model_s: f64,
    /// Modeled advantage of the winner over the `standard` baseline,
    /// `(standard - winner) / standard` (0 when standard wins or was not
    /// evaluated).
    pub margin_vs_standard: f64,
    /// Label of the simulator-fastest algorithm, when the sweep simulated.
    /// Pruning-invariant: an algorithm tying or beating the incumbent is
    /// never pruned, so the first-minimal survivor is the full run's.
    pub sim_winner: Option<&'static str>,
    /// Algorithms whose simulation branch-and-bound pruning skipped in
    /// this cell (0 unless the sweep ran with `prune`).
    pub pruned: usize,
}

/// A model winner change between two adjacent sizes of one regime line.
#[derive(Clone, Debug, PartialEq)]
pub struct ColCrossover {
    pub collective: Collective,
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// Largest size still won by `from`.
    pub size_before: usize,
    /// Smallest size won by `to`.
    pub size_after: usize,
    pub from: &'static str,
    pub to: &'static str,
}

/// The algorithm minimizing total modeled time over one band of one regime
/// line.
#[derive(Clone, Debug, PartialEq)]
pub struct ColRegimeWinner {
    pub collective: Collective,
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// `"small"` (size <= [`SMALL_BAND_MAX`]) or `"large"`.
    pub band: &'static str,
    pub winner: &'static str,
    pub total_model_s: f64,
}

/// The derived collective sweep report.
#[derive(Clone, Debug, Default)]
pub struct CollectiveReport {
    pub winners: Vec<CollectiveWinner>,
    pub crossovers: Vec<ColCrossover>,
    pub regimes: Vec<ColRegimeWinner>,
    /// Branch-and-bound pruning totals (all zero unless the sweep ran with
    /// `prune`); shares the point-to-point sweep's summary shape.
    pub prune: PruneSummary,
}

fn same_line(a: &CollectiveCell, b: &CollectiveCell) -> bool {
    a.collective == b.collective && a.nodes == b.nodes && a.gpus_per_node == b.gpus_per_node
}

fn winners_same_line(a: &CollectiveWinner, b: &CollectiveWinner) -> bool {
    a.collective == b.collective && a.nodes == b.nodes && a.gpus_per_node == b.gpus_per_node
}

/// Analyze collective cells (in engine output order: grid-cell major,
/// algorithms within) into winners, crossovers and regime winners.
pub fn analyze(cells: &[CollectiveCell]) -> CollectiveReport {
    let mut report = CollectiveReport::default();

    // --- Per-cell winners: min model time over each cell's algorithms. ---
    let mut i = 0;
    while i < cells.len() {
        let mut j = i + 1;
        while j < cells.len() && cells[j].index == cells[i].index {
            j += 1;
        }
        let group = &cells[i..j];
        let best = group
            .iter()
            .min_by(|a, b| a.model_s.partial_cmp(&b.model_s).expect("finite model times"))
            .expect("non-empty cell group");
        let sim_winner = group
            .iter()
            .filter(|c| c.sim_s.is_some())
            .min_by(|a, b| a.sim_s.partial_cmp(&b.sim_s).expect("finite sim times"))
            .map(|c| c.algorithm.label());
        let margin = group
            .iter()
            .find(|c| c.algorithm == super::CollectiveAlgorithm::Standard)
            .map(|std| if std.model_s > 0.0 { (std.model_s - best.model_s) / std.model_s } else { 0.0 })
            .unwrap_or(0.0);
        report.winners.push(CollectiveWinner {
            collective: best.collective,
            nodes: best.nodes,
            gpus_per_node: best.gpus_per_node,
            size: best.size,
            winner: best.algorithm.label(),
            model_s: best.model_s,
            margin_vs_standard: margin,
            sim_winner,
            pruned: group.iter().filter(|c| c.sim_pruned).count(),
        });
        i = j;
    }

    // --- Crossovers: winner changes along each regime line (ascending
    // size; the grid emits sizes sorted). ---
    let mut k = 0;
    while k < report.winners.len() {
        let mut j = k + 1;
        while j < report.winners.len() && winners_same_line(&report.winners[j], &report.winners[k]) {
            j += 1;
        }
        for w in report.winners[k..j].windows(2) {
            if w[0].winner != w[1].winner {
                report.crossovers.push(ColCrossover {
                    collective: w[0].collective,
                    nodes: w[0].nodes,
                    gpus_per_node: w[0].gpus_per_node,
                    size_before: w[0].size,
                    size_after: w[1].size,
                    from: w[0].winner,
                    to: w[1].winner,
                });
            }
        }
        k = j;
    }

    // --- Regime winners: per line and band, min total modeled time. ---
    let mut i = 0;
    while i < cells.len() {
        let mut j = i + 1;
        while j < cells.len() && same_line(&cells[j], &cells[i]) {
            j += 1;
        }
        let line = &cells[i..j];
        for (band, want_small) in [("small", true), ("large", false)] {
            let mut totals: BTreeMap<&'static str, f64> = BTreeMap::new();
            for c in line.iter().filter(|c| (c.size <= SMALL_BAND_MAX) == want_small) {
                *totals.entry(c.algorithm.label()).or_default() += c.model_s;
            }
            if totals.is_empty() {
                continue;
            }
            let (&winner, &total) =
                totals.iter().min_by(|a, b| a.1.partial_cmp(b.1).expect("finite totals")).expect("non-empty band");
            report.regimes.push(ColRegimeWinner {
                collective: line[0].collective,
                nodes: line[0].nodes,
                gpus_per_node: line[0].gpus_per_node,
                band,
                winner,
                total_model_s: total,
            });
        }
        i = j;
    }

    // --- Prune accounting. ---
    report.prune = PruneSummary {
        cells: report.winners.len(),
        sim_evals: cells.iter().filter(|c| c.sim_s.is_some()).count(),
        pruned: cells.iter().filter(|c| c.sim_pruned).count(),
    };

    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::CollectiveAlgorithm;

    /// Build a synthetic cell group: standard and locality with fixed
    /// model times.
    fn mk_cells(specs: &[(usize, usize, f64, f64)]) -> Vec<CollectiveCell> {
        // (index, size, t_standard, t_locality)
        let mut out = Vec::new();
        for &(index, size, t_std, t_loc) in specs {
            for (alg, t) in [(CollectiveAlgorithm::Standard, t_std), (CollectiveAlgorithm::Locality, t_loc)] {
                out.push(CollectiveCell {
                    index,
                    collective: Collective::Alltoallv,
                    algorithm: alg,
                    nodes: 32,
                    gpus_per_node: 4,
                    size,
                    model_s: t,
                    sim_s: Some(t * 1.1),
                    stages: if alg == CollectiveAlgorithm::Standard { 1 } else { 3 },
                    internode_msgs: 100,
                    internode_bytes: 100 * size,
                    sim_pruned: false,
                });
            }
        }
        out
    }

    #[test]
    fn winners_margin_and_crossover_detected() {
        // Locality wins the two small cells, standard takes the large one.
        let cells = mk_cells(&[(0, 512, 2.0, 1.0), (1, 4096, 2.0, 1.5), (2, 1 << 20, 4.0, 9.0)]);
        let r = analyze(&cells);
        assert_eq!(r.winners.len(), 3);
        assert_eq!(r.winners[0].winner, "locality");
        assert!((r.winners[0].margin_vs_standard - 0.5).abs() < 1e-12);
        assert_eq!(r.winners[2].winner, "standard");
        assert_eq!(r.winners[2].margin_vs_standard, 0.0);
        assert_eq!(r.crossovers.len(), 1);
        let x = &r.crossovers[0];
        assert_eq!((x.size_before, x.size_after), (4096, 1 << 20));
        assert_eq!((x.from, x.to), ("locality", "standard"));
    }

    #[test]
    fn regime_winners_locality_small_standard_large() {
        let cells = mk_cells(&[(0, 512, 2.0, 1.0), (1, 4096, 2.0, 1.5), (2, 1 << 20, 4.0, 9.0)]);
        let r = analyze(&cells);
        assert_eq!(r.regimes.len(), 2);
        let small = r.regimes.iter().find(|g| g.band == "small").unwrap();
        assert_eq!(small.winner, "locality");
        assert!((small.total_model_s - 2.5).abs() < 1e-12);
        let large = r.regimes.iter().find(|g| g.band == "large").unwrap();
        assert_eq!(large.winner, "standard");
    }

    #[test]
    fn sim_winner_tracked_separately() {
        let mut cells = mk_cells(&[(0, 512, 1.0, 2.0)]);
        cells[0].sim_s = Some(5.0);
        cells[1].sim_s = Some(0.5);
        let r = analyze(&cells);
        assert_eq!(r.winners[0].winner, "standard");
        assert_eq!(r.winners[0].sim_winner, Some("locality"));
    }

    #[test]
    fn empty_input_empty_report() {
        let r = analyze(&[]);
        assert!(r.winners.is_empty() && r.crossovers.is_empty() && r.regimes.is_empty());
    }
}
