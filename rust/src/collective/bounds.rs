//! Closed-form cost bounds for branch-and-bound collective pruning.
//!
//! The point-to-point sweep prunes simulations with `model::bounds`
//! (protocol envelopes + a conservative simulator floor); this module is
//! the collective-layer analogue. For a materialized [`Lowering`] it
//! derives a `[lower, upper]` interval such that
//!
//! - `lower <= algorithm_time(lowering) <= upper`
//!   ([`super::model::algorithm_time`]), and
//! - `lower <= simulated time` of [`super::lower::sim_schedule`],
//!
//! which makes `collective --prune` winner-preserving: an algorithm whose
//! `lower` exceeds the best simulated time in a cell cannot be the cell's
//! simulated winner and may skip the simulator.
//!
//! # Construction
//!
//! **Envelopes.** The collective model composes exactly three
//! size-dependent protocol lookups — the off-node row inside
//! [`super::model::net_time`], the on-node rows inside
//! [`super::model::intra_serial`], and the (size-independent, exact)
//! memcpy rows of the copy legs. Re-evaluating the same composition with
//! the component-wise min/max envelopes of [`crate::model::bounds`]
//! brackets every stage term, and the per-stage combinators
//! (`max(net, intra) + copies`, stage sums, pairwise round sums) are all
//! monotone, so the composition brackets the whole algorithm time.
//!
//! **Simulator floor.** Stages are barrier-separated phases in
//! [`super::lower::sim_schedule`], so per-stage occupancy floors *sum*:
//!
//! - every inter-node byte of a stage crosses some NIC rail of its source
//!   node during that stage, and some rail carries at least `1/nics` of
//!   the busiest node's injection (pigeonhole);
//! - a sender's transfers serialize, so the busiest inter-node sender
//!   pays at least `max(m · α_min, bytes · β_min)`;
//! - standard/locality stages run staged D2H/H2D copy phases around any
//!   inter-node exchange; pairwise pays the pair once for the whole
//!   schedule.
//!
//! Because the floor is computed from the *materialized* lowering, the
//! duplicate/dedup accounting is structurally identical to
//! [`Lowering::internode_msgs`]/[`Lowering::internode_bytes`] — the
//! deduplicated exchange stage contributes exactly its deduplicated
//! bytes. The caller-facing `lower` folds the floor in through the same
//! [`SAFETY`] margin the point-to-point bounds use.

use super::lower::Lowering;
use super::model::{copy_legs, peak_volumes};
use super::CollectiveAlgorithm;
use crate::model::bounds::{CostBounds, Envelope, SAFETY};
use crate::model::{copy, maxrate::MaxRate};
use crate::params::{AlphaBeta, CopyDir, Endpoint, MachineParams};
use crate::pattern::CommPattern;
use crate::topology::{GpuId, Locality, Machine, NodeId};
use std::collections::BTreeMap;

/// Bound evaluator for one `(machine, params)` pair — the collective
/// analogue of [`crate::model::BoundModel`], returning intervals around
/// [`super::model::algorithm_time`] instead of point estimates.
#[derive(Clone, Debug)]
pub struct ColBoundModel<'a> {
    machine: &'a Machine,
    params: &'a MachineParams,
    lo: Envelope,
    hi: Envelope,
}

impl<'a> ColBoundModel<'a> {
    pub fn new(machine: &'a Machine, params: &'a MachineParams) -> Self {
        ColBoundModel { machine, params, lo: Envelope::build(params, false), hi: Envelope::build(params, true) }
    }

    /// The `[lower, upper]` interval for one lowered collective.
    pub fn bounds(&self, lowering: &Lowering) -> CostBounds {
        let upper = self.env_algorithm_time(&self.hi, lowering);
        let env_lower = self.env_algorithm_time(&self.lo, lowering);
        let lower = env_lower.min(SAFETY * self.sim_floor(lowering));
        CostBounds { lower, upper }
    }

    /// [`super::model::net_time`] with the size-selected off-node row
    /// replaced by the envelope coefficients.
    fn env_net_time(&self, env: &Envelope, pattern: &CommPattern) -> f64 {
        let st = pattern.stats(self.machine);
        if st.m_std == 0 {
            return 0.0;
        }
        let ab = env.ab(Endpoint::Cpu, Locality::OffNode);
        let mr = MaxRate { alpha: ab.alpha, rb: 1.0 / ab.beta, rn: self.params.rn() };
        mr.time_node_rails(st.m_std, st.s_proc, st.s_node, self.machine.nics_per_node())
    }

    /// [`super::model::intra_serial`] with the per-size on-node rows
    /// replaced by the envelope coefficients.
    fn env_intra_serial(&self, env: &Envelope, pattern: &CommPattern) -> f64 {
        let mut send: BTreeMap<GpuId, f64> = BTreeMap::new();
        let mut recv: BTreeMap<GpuId, f64> = BTreeMap::new();
        for m in pattern.intranode(self.machine) {
            let t = env.ab(Endpoint::Cpu, self.machine.gpu_locality(m.src, m.dst)).time(m.bytes);
            *send.entry(m.src).or_default() += t;
            *recv.entry(m.dst).or_default() += t;
        }
        let worst = |m: &BTreeMap<GpuId, f64>| m.values().fold(0.0f64, |a, &b| a.max(b));
        worst(&send).max(worst(&recv))
    }

    /// [`super::model::stage_time`] under an envelope. The copy legs are
    /// size-independent memcpy rows — exact at both ends of the interval.
    fn env_stage_time(&self, env: &Envelope, pattern: &CommPattern) -> f64 {
        self.env_net_time(env, pattern).max(self.env_intra_serial(env, pattern))
            + copy_legs(self.machine, self.params, pattern)
    }

    /// [`super::model::algorithm_time`] under an envelope: same stage
    /// combinators, envelope legs.
    fn env_algorithm_time(&self, env: &Envelope, lowering: &Lowering) -> f64 {
        match lowering.algorithm {
            CollectiveAlgorithm::Standard | CollectiveAlgorithm::Locality => {
                lowering.stages.iter().map(|s| self.env_stage_time(env, &s.pattern)).sum()
            }
            CollectiveAlgorithm::Pairwise => {
                let (out_max, in_max) = peak_volumes(
                    lowering.stages.iter().flat_map(|s| s.pattern.msgs.iter().map(|m| (m.src, m.dst, m.bytes))),
                );
                let copies =
                    if out_max + in_max > 0 { copy::t_copy(self.params, out_max, in_max, 1) } else { 0.0 };
                copies
                    + lowering
                        .stages
                        .iter()
                        .map(|s| {
                            let inter = self.env_net_time(env, &s.pattern);
                            if inter > 0.0 {
                                inter
                            } else {
                                self.env_intra_serial(env, &s.pattern)
                            }
                        })
                        .sum::<f64>()
            }
        }
    }

    /// Occupancy floor on the simulated schedule: per-stage floors summed
    /// (stages are barriers), copy-phase latencies per the algorithm's
    /// staging shape. Deliberately conservative — intra-node traffic
    /// contributes nothing, sender floors use `max` instead of the serial
    /// sum — and the caller scales by [`SAFETY`].
    fn sim_floor(&self, lowering: &Lowering) -> f64 {
        let p = self.params;
        let nics = self.machine.nics_per_node().max(1);
        let band_beta = (0..nics).map(|r| p.nic_band(r).beta).fold(f64::INFINITY, f64::min);
        let ab = self.lo.ab(Endpoint::Cpu, Locality::OffNode);
        let byte_beta = band_beta.min(ab.beta);
        let a_min = |dir| {
            let a1: AlphaBeta = p.memcpy_ab(dir, 1);
            let a4: AlphaBeta = p.memcpy_ab(dir, 4);
            a1.alpha.min(a4.alpha)
        };
        let copy_alphas = a_min(CopyDir::D2H) + a_min(CopyDir::H2D);
        let per_stage_copies =
            matches!(lowering.algorithm, CollectiveAlgorithm::Standard | CollectiveAlgorithm::Locality);

        let mut floor = 0.0f64;
        let mut any_internode = false;
        for stage in &lowering.stages {
            let mut node_bytes: BTreeMap<NodeId, usize> = BTreeMap::new();
            let mut senders: BTreeMap<GpuId, (usize, usize)> = BTreeMap::new();
            for m in stage.pattern.internode(self.machine) {
                *node_bytes.entry(self.machine.gpu_node(m.src)).or_default() += m.bytes;
                let e = senders.entry(m.src).or_default();
                e.0 += 1;
                e.1 += m.bytes;
            }
            if node_bytes.is_empty() {
                continue;
            }
            any_internode = true;
            let s_node = node_bytes.values().copied().max().unwrap_or(0);
            let rail = s_node as f64 * byte_beta / nics as f64;
            let sender = senders
                .values()
                .map(|&(m, s)| (m as f64 * ab.alpha).max(s as f64 * ab.beta))
                .fold(0.0f64, f64::max);
            floor += rail.max(sender);
            if per_stage_copies {
                floor += copy_alphas;
            }
        }
        if !per_stage_copies && any_internode {
            // pairwise: payloads stay host-resident across rounds — one
            // D2H before the first round, one H2D after the last
            floor += copy_alphas;
        }
        floor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{lower, Collective, CollectiveSpec};
    use crate::collective::model::algorithm_time;
    use crate::params::lassen_params;
    use crate::topology::machines::lassen;

    #[test]
    fn envelope_brackets_the_model_everywhere() {
        let params = lassen_params();
        for nodes in [2, 4, 8, 32] {
            let machine = lassen(nodes);
            let bm = ColBoundModel::new(&machine, &params);
            for c in Collective::ALL {
                for exp in [9, 13, 17, 19] {
                    let direct = CollectiveSpec::new(c, 1usize << exp, 42).materialize(&machine);
                    for alg in CollectiveAlgorithm::ALL {
                        let lowering = lower(c, alg, &machine, &direct);
                        let t = algorithm_time(&machine, &params, &lowering);
                        let b = bm.bounds(&lowering);
                        assert!(
                            b.lower <= t && t <= b.upper,
                            "{c} {alg} n={nodes} s=2^{exp}: {t:e} not in [{:e}, {:e}]",
                            b.lower,
                            b.upper
                        );
                        assert!(b.lower.is_finite() && b.upper.is_finite());
                        assert!(b.lower > 0.0, "{c} {alg}: zero lower bound prunes nothing");
                    }
                }
            }
        }
    }

    #[test]
    fn floor_respects_dedup_accounting() {
        // Allgather's locality lowering ships each duplicate group once per
        // destination node; the floor must see the deduplicated volume, so
        // it cannot exceed the one computed for the duplicate-free alltoall
        // of the same block size (same exchange volume, same shape).
        let params = lassen_params();
        let machine = lassen(8);
        let bm = ColBoundModel::new(&machine, &params);
        let block = 4096;
        let ag = CollectiveSpec::new(Collective::Allgather, block, 42).materialize(&machine);
        let a2a = CollectiveSpec::new(Collective::Alltoall, block, 42).materialize(&machine);
        let l_ag = lower(Collective::Allgather, CollectiveAlgorithm::Locality, &machine, &ag);
        let l_a2a = lower(Collective::Alltoall, CollectiveAlgorithm::Locality, &machine, &a2a);
        assert_eq!(l_ag.internode_bytes(&machine), l_a2a.internode_bytes(&machine));
        let (b_ag, b_a2a) = (bm.bounds(&l_ag), bm.bounds(&l_a2a));
        assert!(b_ag.lower <= b_a2a.upper, "dedup accounting must not inflate the allgather floor");
    }

    #[test]
    fn pairwise_floor_scales_with_rounds() {
        // Each inter-node round is a barrier phase; the summed floor must
        // grow with the node count at a fixed block size.
        let params = lassen_params();
        let lowered = |nodes: usize| {
            let machine = lassen(nodes);
            let d = CollectiveSpec::new(Collective::Alltoall, 512, 42).materialize(&machine);
            let l = lower(Collective::Alltoall, CollectiveAlgorithm::Pairwise, &machine, &d);
            let bm = ColBoundModel::new(&machine, &params);
            bm.bounds(&l).lower
        };
        assert!(lowered(16) > 2.0 * lowered(4), "pairwise floor must scale with round count");
    }
}
