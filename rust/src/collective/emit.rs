//! Deterministic emitters for collective sweep results: JSON
//! (`hetcomm.collective.v1`, byte-identical across seeded runs), CSV (one
//! row per cell × algorithm) and aligned text tables. Hand-rolled like
//! [`crate::sweep::emit`] — no `serde` in the offline image, fixed float
//! formatting.

use super::sweep::CollectiveResult;
use crate::bench::{fmt_secs, Table};
use crate::sweep::emit::esc;
use std::fmt::Write as _;

/// Fixed-width scientific float formatting: deterministic and valid JSON.
fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.9e}")
    } else {
        "null".to_string()
    }
}

fn opt_num(x: Option<f64>) -> String {
    match x {
        Some(v) => num(v),
        None => "null".to_string(),
    }
}

fn usize_list(xs: &[usize]) -> String {
    let items: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("[{}]", items.join(", "))
}

fn label_list<T: std::fmt::Display>(xs: &[T]) -> String {
    let items: Vec<String> = xs.iter().map(|x| format!("\"{x}\"")).collect();
    format!("[{}]", items.join(", "))
}

/// True when the sweep ran with branch-and-bound pruning: the emitters
/// then carry `sim_pruned` / `pruned` fields and the prune summary.
/// Flag-less sweeps emit no prune fields at all (CI grep-gates this).
fn pruned(result: &CollectiveResult) -> bool {
    result.config.prune
}

/// True when refinement could actually skip cells. With at most two
/// points on both refinable axes the initial lattice already covers the
/// grid, the run is byte-identical to an exhaustive one, and it must
/// serialize identically too — so the `refine` echo is suppressed.
fn refined(result: &CollectiveResult) -> bool {
    let g = &result.config.grid;
    let mut sizes = g.sizes.clone();
    sizes.sort_unstable();
    sizes.dedup();
    result.config.refine > 0 && (sizes.len() > 2 || g.nodes.len() > 2)
}

/// Serialize the full collective sweep result (config echo, cells, report)
/// as JSON. Wall-clock fields are deliberately excluded: two runs with the
/// same seed must produce byte-identical output.
pub fn to_json(result: &CollectiveResult) -> String {
    let cfg = &result.config;
    let pruned = pruned(result);
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"schema\": \"hetcomm.collective.v1\",");
    let _ = writeln!(out, "  \"machine\": \"{}\",", esc(&cfg.machine));
    let _ = writeln!(out, "  \"seed\": {},", cfg.seed);
    let _ = writeln!(out, "  \"sim\": {},", cfg.sim);
    let _ = writeln!(out, "  \"collectives\": {},", label_list(&cfg.grid.collectives));
    let _ = writeln!(out, "  \"algorithms\": {},", label_list(&cfg.grid.algorithms));
    let _ = writeln!(out, "  \"nodes\": {},", usize_list(&cfg.grid.nodes));
    let _ = writeln!(out, "  \"gpus_per_node\": {},", usize_list(&cfg.grid.gpus_per_node));
    let _ = writeln!(out, "  \"sizes\": {},", usize_list(&cfg.grid.sizes));
    if refined(result) {
        let _ = writeln!(out, "  \"refine\": {},", cfg.refine);
    }

    out.push_str("  \"cells\": [\n");
    for (i, c) in result.cells.iter().enumerate() {
        let comma = if i + 1 < result.cells.len() { "," } else { "" };
        let skip = if pruned { format!(", \"sim_pruned\": {}", c.sim_pruned) } else { String::new() };
        let _ = writeln!(
            out,
            "    {{\"collective\": \"{}\", \"algorithm\": \"{}\", \"nodes\": {}, \"gpus_per_node\": {}, \
             \"size\": {}, \"model_s\": {}, \"sim_s\": {}, \"stages\": {}, \"internode_msgs\": {}, \
             \"internode_bytes\": {}{skip}}}{comma}",
            c.collective,
            c.algorithm,
            c.nodes,
            c.gpus_per_node,
            c.size,
            num(c.model_s),
            opt_num(c.sim_s),
            c.stages,
            c.internode_msgs,
            c.internode_bytes,
        );
    }
    out.push_str("  ],\n");

    out.push_str("  \"winners\": [\n");
    for (i, w) in result.report.winners.iter().enumerate() {
        let comma = if i + 1 < result.report.winners.len() { "," } else { "" };
        let sim_winner = match &w.sim_winner {
            Some(s) => format!("\"{}\"", esc(s)),
            None => "null".to_string(),
        };
        let skip = if pruned { format!(", \"pruned\": {}", w.pruned) } else { String::new() };
        let _ = writeln!(
            out,
            "    {{\"collective\": \"{}\", \"nodes\": {}, \"gpus_per_node\": {}, \"size\": {}, \
             \"winner\": \"{}\", \"model_s\": {}, \"margin_vs_standard\": {}, \"sim_winner\": {}{skip}}}{comma}",
            w.collective,
            w.nodes,
            w.gpus_per_node,
            w.size,
            esc(w.winner),
            num(w.model_s),
            num(w.margin_vs_standard),
            sim_winner,
        );
    }
    out.push_str("  ],\n");

    out.push_str("  \"crossovers\": [\n");
    for (i, x) in result.report.crossovers.iter().enumerate() {
        let comma = if i + 1 < result.report.crossovers.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "    {{\"collective\": \"{}\", \"nodes\": {}, \"gpus_per_node\": {}, \"size_before\": {}, \
             \"size_after\": {}, \"from\": \"{}\", \"to\": \"{}\"}}{comma}",
            x.collective,
            x.nodes,
            x.gpus_per_node,
            x.size_before,
            x.size_after,
            esc(x.from),
            esc(x.to),
        );
    }
    out.push_str("  ],\n");

    out.push_str("  \"regimes\": [\n");
    for (i, g) in result.report.regimes.iter().enumerate() {
        let comma = if i + 1 < result.report.regimes.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "    {{\"collective\": \"{}\", \"nodes\": {}, \"gpus_per_node\": {}, \"band\": \"{}\", \
             \"winner\": \"{}\", \"total_model_s\": {}}}{comma}",
            g.collective,
            g.nodes,
            g.gpus_per_node,
            g.band,
            esc(g.winner),
            num(g.total_model_s),
        );
    }
    if pruned {
        out.push_str("  ],\n");
        let p = &result.report.prune;
        let _ = writeln!(
            out,
            "  \"prune\": {{\"cells\": {}, \"sim_evals\": {}, \"pruned\": {}}}",
            p.cells, p.sim_evals, p.pruned
        );
    } else {
        out.push_str("  ]\n");
    }
    out.push_str("}\n");
    out
}

/// One CSV row per (cell × algorithm).
pub fn to_csv(result: &CollectiveResult) -> String {
    let pruned = pruned(result);
    let mut out = String::from(
        "collective,algorithm,nodes,gpus_per_node,size,model_s,sim_s,stages,internode_msgs,internode_bytes",
    );
    if pruned {
        out.push_str(",sim_pruned");
    }
    out.push('\n');
    for c in &result.cells {
        let skip = if pruned { format!(",{}", c.sim_pruned) } else { String::new() };
        let _ = writeln!(
            out,
            "{},{},{},{},{},{},{},{},{},{}{skip}",
            c.collective,
            c.algorithm,
            c.nodes,
            c.gpus_per_node,
            c.size,
            num(c.model_s),
            c.sim_s.map(num).unwrap_or_default(),
            c.stages,
            c.internode_msgs,
            c.internode_bytes,
        );
    }
    out
}

/// Human-readable view: one table per (collective, nodes, gpn) line
/// (sizes × algorithms, modeled seconds, winner and margin columns), then
/// the crossover and regime-winner report.
pub fn render_tables(result: &CollectiveResult) -> String {
    let mut out = String::new();
    let algorithms = &result.config.grid.algorithms;
    let cells = &result.cells;

    let mut i = 0;
    while i < cells.len() {
        let mut j = i + 1;
        while j < cells.len()
            && cells[j].collective == cells[i].collective
            && cells[j].nodes == cells[i].nodes
            && cells[j].gpus_per_node == cells[i].gpus_per_node
        {
            j += 1;
        }
        let line = &cells[i..j];
        let mut header: Vec<String> = vec!["size[B]".into()];
        header.extend(algorithms.iter().map(|a| a.label().to_string()));
        header.push("winner".into());
        header.push("vs standard".into());
        let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            format!("{} · {} nodes · {} GPUs/node", line[0].collective, line[0].nodes, line[0].gpus_per_node),
            &hdr,
        );
        let mut k = i;
        while k < j {
            let mut m = k + 1;
            while m < j && cells[m].index == cells[k].index {
                m += 1;
            }
            let group = &cells[k..m];
            let mut row = vec![group[0].size.to_string()];
            for a in algorithms {
                match group.iter().find(|c| c.algorithm == *a) {
                    Some(c) => row.push(fmt_secs(c.model_s)),
                    None => row.push(String::new()),
                }
            }
            let win = result.report.winners.iter().find(|w| {
                w.collective == group[0].collective
                    && w.nodes == group[0].nodes
                    && w.gpus_per_node == group[0].gpus_per_node
                    && w.size == group[0].size
            });
            row.push(win.map(|w| w.winner.to_string()).unwrap_or_default());
            row.push(win.map(|w| format!("{:+.1}%", w.margin_vs_standard * 100.0)).unwrap_or_default());
            t.row(row);
            k = m;
        }
        out.push_str(&t.render());
        i = j;
    }

    out.push_str("\nCrossover report (model winner changes with block size):\n");
    if result.report.crossovers.is_empty() {
        out.push_str("  (none within the swept sizes)\n");
    }
    for x in &result.report.crossovers {
        let _ = writeln!(
            out,
            "  {} · {} nodes · {} GPUs/node: {} -> {} between {} B and {} B",
            x.collective, x.nodes, x.gpus_per_node, x.from, x.to, x.size_before, x.size_after
        );
    }

    out.push_str("\nRegime winners (min total modeled time per band):\n");
    for g in &result.report.regimes {
        let _ = writeln!(
            out,
            "  {} · {} nodes · {} GPUs/node · {:>5}: {} ({})",
            g.collective,
            g.nodes,
            g.gpus_per_node,
            g.band,
            g.winner,
            fmt_secs(g.total_model_s).trim()
        );
    }
    if pruned(result) {
        let p = &result.report.prune;
        let _ = writeln!(
            out,
            "\nBound-guided pruning: skipped {} of {} algorithm simulations over {} cells",
            p.pruned,
            p.pruned + p.sim_evals,
            p.cells
        );
    }
    if refined(result) {
        let total = result.config.grid.cells().len();
        let _ = writeln!(
            out,
            "\nAdaptive refinement (depth {}): {} of {} grid cells evaluated",
            result.config.refine,
            result.report.prune.cells,
            total
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::sweep::{run_collective, CollectiveConfig, CollectiveGrid};

    fn tiny_config() -> CollectiveConfig {
        CollectiveConfig { grid: CollectiveGrid::tiny(), seed: 3, threads: 1, ..Default::default() }
    }

    fn tiny_result() -> CollectiveResult {
        run_collective(&tiny_config()).unwrap()
    }

    #[test]
    fn json_has_sections_and_no_wallclock() {
        let r = tiny_result();
        let j = to_json(&r);
        for key in
            ["\"schema\": \"hetcomm.collective.v1\"", "\"cells\"", "\"winners\"", "\"crossovers\"", "\"regimes\""]
        {
            assert!(j.contains(key), "missing {key}");
        }
        assert!(!j.contains("elapsed"), "wall-clock leaked into deterministic output");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn csv_row_count_and_header() {
        let r = tiny_result();
        let csv = to_csv(&r);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 1 + r.cells.len());
        assert!(lines[0].starts_with("collective,algorithm,nodes"));
    }

    #[test]
    fn emission_is_byte_deterministic() {
        let a = tiny_result();
        let b = tiny_result();
        assert_eq!(to_json(&a), to_json(&b));
        assert_eq!(to_csv(&a), to_csv(&b));
        assert_eq!(render_tables(&a), render_tables(&b));
    }

    #[test]
    fn tables_mention_every_algorithm_and_sections() {
        let r = tiny_result();
        let text = render_tables(&r);
        for a in &r.config.grid.algorithms {
            assert!(text.contains(a.label()), "missing {}", a.label());
        }
        assert!(text.contains("Crossover report"));
        assert!(text.contains("Regime winners"));
        assert!(text.contains("vs standard"));
    }

    #[test]
    fn default_runs_emit_no_prune_or_refine_fields() {
        let r = tiny_result();
        for tok in ["sim_pruned", "\"pruned\"", "\"prune\"", "\"refine\""] {
            assert!(!to_json(&r).contains(tok), "flag-less JSON leaked {tok}");
        }
        assert!(!to_csv(&r).contains("sim_pruned"));
        let text = render_tables(&r);
        assert!(!text.contains("pruning") && !text.contains("refinement"));
    }

    #[test]
    fn pruned_runs_carry_prune_fields_everywhere() {
        let mut cfg = tiny_config();
        cfg.prune = true;
        let r = run_collective(&cfg).unwrap();
        let j = to_json(&r);
        for tok in ["\"sim_pruned\": ", "\"pruned\": ", "\"prune\": {\"cells\": "] {
            assert!(j.contains(tok), "pruned JSON missing {tok}");
        }
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        let csv = to_csv(&r);
        assert!(csv.lines().next().unwrap().ends_with(",sim_pruned"));
        assert!(render_tables(&r).contains("Bound-guided pruning"));
    }

    #[test]
    fn refine_echo_suppressed_when_it_cannot_skip_cells() {
        // tiny grid: 2 nodes x 2 sizes — the lattice covers everything, so
        // the refined output must serialize byte-identically to exhaustive.
        let mut cfg = tiny_config();
        cfg.refine = 2;
        let noop = run_collective(&cfg).unwrap();
        assert_eq!(to_json(&tiny_result()), to_json(&noop));
        assert!(!render_tables(&noop).contains("Adaptive refinement"));
        // a grid with interior points does echo the depth
        let mut cfg = tiny_config();
        cfg.grid.sizes = vec![512, 1 << 12, 1 << 14];
        cfg.refine = 1;
        let r = run_collective(&cfg).unwrap();
        assert!(to_json(&r).contains("\"refine\": 1,"));
        assert!(render_tables(&r).contains("Adaptive refinement (depth 1):"));
    }
}
