//! Compiled collective decision surfaces: the composed Table 6 collective
//! models ([`super::model`]) evaluated once over a (collective × nodes ×
//! size) lattice at a fixed GPUs-per-node count, so the advisor answers
//! "which algorithm for this alltoallv at this scale?" with a lattice read
//! instead of synthesizing and lowering patterns.
//!
//! Queries interpolate in log₂-space along the size axis and snap to the
//! nearest lattice value on the node axis, the same discipline as
//! [`crate::advisor::DecisionSurface`]; at lattice points the stored model
//! times come back bit-for-bit.

use super::{algorithm_time, lower, Collective, CollectiveAlgorithm, CollectiveSpec};
use crate::topology::machines;
use crate::util::rng::index_seed;

/// Ranked algorithms for one query, fastest first (ties keep
/// [`CollectiveAlgorithm::ALL`] order).
#[derive(Clone, Debug, PartialEq)]
pub struct RankedAlgorithms {
    /// `(algorithm, predicted seconds)`, ascending by time.
    pub ranked: Vec<(CollectiveAlgorithm, f64)>,
}

impl RankedAlgorithms {
    /// The winning algorithm and its predicted time.
    pub fn best(&self) -> (CollectiveAlgorithm, f64) {
        self.ranked[0]
    }
}

/// A compiled per-machine collective decision surface.
#[derive(Clone, Debug, PartialEq)]
pub struct CollectiveSurface {
    /// Canonical registry name of the machine ([`machines::parse`]).
    pub machine: String,
    /// GPUs per node the lattice was evaluated at.
    pub gpus_per_node: usize,
    /// Base seed of the lattice synthesis (fixes alltoallv's irregular
    /// counts; each lattice point derives its own sub-seed by flat index).
    pub seed: u64,
    /// Collectives on the lattice, in [`Collective::ALL`] order.
    pub collectives: Vec<Collective>,
    /// Node-count axis (strictly ascending).
    pub nodes: Vec<usize>,
    /// Block-size axis [bytes] (strictly ascending).
    pub sizes: Vec<usize>,
    /// Algorithms evaluated per cell, in [`CollectiveAlgorithm::ALL`] order.
    pub algorithms: Vec<CollectiveAlgorithm>,
    /// Modeled seconds per lattice cell × algorithm; cells are in
    /// row-major (collective, nodes, size) order — size fastest.
    pub cells: Vec<Vec<f64>>,
}

/// Log-space linear interpolation that returns the endpoints bit-exactly
/// at the boundary weights (lattice-point lookups reproduce stored values).
fn lerp_log(a: f64, b: f64, w: f64) -> f64 {
    if w <= 0.0 {
        a
    } else if w >= 1.0 {
        b
    } else {
        (a.ln() * (1.0 - w) + b.ln() * w).exp()
    }
}

/// Bracketing indices and log₂-space weight for `v` on a sorted axis;
/// clamps outside the range, degenerates to one index on exact hits.
fn bracket(axis: &[usize], v: usize) -> (usize, usize, f64) {
    if v <= axis[0] {
        return (0, 0, 0.0);
    }
    if v >= *axis.last().expect("validated axis") {
        let i = axis.len() - 1;
        return (i, i, 0.0);
    }
    let hi = axis.partition_point(|&a| a < v);
    if axis[hi] == v {
        return (hi, hi, 0.0);
    }
    let lo = hi - 1;
    let (x0, x1) = ((axis[lo] as f64).log2(), (axis[hi] as f64).log2());
    (lo, hi, ((v as f64).log2() - x0) / (x1 - x0))
}

/// Index of the axis value nearest `v` in log₂ space (ties toward smaller).
fn nearest(axis: &[usize], v: usize) -> usize {
    let lv = (v.max(1) as f64).log2();
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for (i, &a) in axis.iter().enumerate() {
        let d = ((a as f64).log2() - lv).abs();
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

impl CollectiveSurface {
    /// The default serving lattice: the collective characterization ranges.
    pub fn default_nodes() -> Vec<usize> {
        vec![2, 4, 8, 16, 32]
    }

    /// Default block-size axis [bytes].
    pub fn default_sizes() -> Vec<usize> {
        (9..=19).step_by(2).map(|e| 1usize << e).collect()
    }

    /// Compile a surface: evaluate the composed collective models at every
    /// lattice point (model-only — no simulation). Deterministic — two
    /// compiles of the same spec produce bit-identical surfaces.
    pub fn compile(
        machine: &str,
        gpus_per_node: usize,
        mut nodes: Vec<usize>,
        mut sizes: Vec<usize>,
        seed: u64,
    ) -> Result<CollectiveSurface, String> {
        let (arch, params) = machines::parse(machine, 1)?;
        if gpus_per_node < 2 || gpus_per_node % arch.sockets_per_node != 0 {
            return Err(format!(
                "{gpus_per_node} GPUs/node does not divide over the {} sockets of {}",
                arch.sockets_per_node, arch.name
            ));
        }
        for axis in [&mut nodes, &mut sizes] {
            axis.sort_unstable();
            axis.dedup();
        }
        if nodes.is_empty() || nodes[0] < 2 {
            return Err("collective surface node axis must be non-empty with values >= 2".into());
        }
        if sizes.is_empty() || sizes[0] == 0 {
            return Err("collective surface size axis must be non-empty and positive".into());
        }
        let collectives = Collective::ALL.to_vec();
        let algorithms = CollectiveAlgorithm::ALL.to_vec();
        let mut cells = Vec::with_capacity(collectives.len() * nodes.len() * sizes.len());
        for &collective in &collectives {
            for &n in &nodes {
                for &s in &sizes {
                    let m = machines::with_shape(&arch, n, gpus_per_node);
                    let spec = CollectiveSpec::new(collective, s, index_seed(seed, cells.len()));
                    let direct = spec.materialize(&m);
                    let times = algorithms
                        .iter()
                        .map(|&a| algorithm_time(&m, &params, &lower(collective, a, &m, &direct)))
                        .collect();
                    cells.push(times);
                }
            }
        }
        let surface = CollectiveSurface {
            machine: arch.name.clone(),
            gpus_per_node,
            seed,
            collectives,
            nodes,
            sizes,
            algorithms,
            cells,
        };
        surface.validate()?;
        Ok(surface)
    }

    /// Structural sanity (used after artifact loads); returns a user-facing
    /// message on failure.
    pub fn validate(&self) -> Result<(), String> {
        for (name, axis) in [("nodes", &self.nodes), ("sizes", &self.sizes)] {
            if axis.is_empty() {
                return Err(format!("collective surface axis {name:?} is empty"));
            }
            if axis.iter().any(|&v| v == 0) {
                return Err(format!("collective surface axis {name:?} has a zero value"));
            }
            if axis.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("collective surface axis {name:?} must be strictly ascending"));
            }
        }
        if self.nodes[0] < 2 {
            return Err("collective surface node axis must start at >= 2".into());
        }
        if self.collectives.is_empty() || self.algorithms.is_empty() {
            return Err("collective surface has no collectives or no algorithms".into());
        }
        if self.cells.len() != self.collectives.len() * self.nodes.len() * self.sizes.len() {
            return Err(format!(
                "collective surface has {} cells, axes imply {}",
                self.cells.len(),
                self.collectives.len() * self.nodes.len() * self.sizes.len()
            ));
        }
        for (i, cell) in self.cells.iter().enumerate() {
            if cell.len() != self.algorithms.len() {
                return Err(format!("cell {i} has {} times, expected {}", cell.len(), self.algorithms.len()));
            }
            if cell.iter().any(|t| !t.is_finite() || *t <= 0.0) {
                return Err(format!("cell {i} holds a non-positive or non-finite time"));
            }
        }
        let (arch, _) = machines::parse(&self.machine, 1)?;
        if self.gpus_per_node < 2 || self.gpus_per_node % arch.sockets_per_node != 0 {
            return Err(format!(
                "surface claims {} GPUs/node, which does not divide over the {} sockets of {}",
                self.gpus_per_node, arch.sockets_per_node, arch.name
            ));
        }
        Ok(())
    }

    /// Flat cell index; size is the fastest axis.
    fn index(&self, ci: usize, ni: usize, si: usize) -> usize {
        (ci * self.nodes.len() + ni) * self.sizes.len() + si
    }

    /// Interpolated lookup: log₂-space interpolation along the size axis,
    /// nearest lattice value on the node axis; queries outside the lattice
    /// clamp to the boundary. Returns `None` when the surface does not
    /// cover `collective`. At lattice points the stored model times come
    /// back bit-for-bit.
    pub fn lookup(&self, collective: Collective, nodes: usize, size: usize) -> Option<RankedAlgorithms> {
        let ci = self.collectives.iter().position(|&c| c == collective)?;
        let ni = nearest(&self.nodes, nodes);
        let (s0, s1, ws) = bracket(&self.sizes, size);
        let r0 = &self.cells[self.index(ci, ni, s0)];
        let r1 = &self.cells[self.index(ci, ni, s1)];
        let mut ranked: Vec<(CollectiveAlgorithm, f64)> = self
            .algorithms
            .iter()
            .enumerate()
            .map(|(k, &a)| (a, lerp_log(r0[k], r1[k], ws)))
            .collect();
        ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("finite surface times"));
        Some(RankedAlgorithms { ranked })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CollectiveSurface {
        CollectiveSurface::compile("lassen", 4, vec![2, 4, 32], vec![512, 8192, 1 << 19], 42).unwrap()
    }

    #[test]
    fn compile_shape_and_determinism() {
        let a = tiny();
        assert_eq!(a.cells.len(), 3 * 3 * 3);
        assert_eq!(a.machine, "lassen");
        a.validate().unwrap();
        let b = tiny();
        assert_eq!(a, b, "compile must be deterministic");
    }

    #[test]
    fn lattice_lookup_is_exact() {
        let s = tiny();
        let r = s.lookup(Collective::Alltoallv, 4, 8192).unwrap();
        let idx = s.index(1, 1, 1); // alltoallv, nodes=4, size=8192
        for (alg, t) in &r.ranked {
            let k = s.algorithms.iter().position(|a| a == alg).unwrap();
            assert_eq!(t.to_bits(), s.cells[idx][k].to_bits(), "{alg}");
        }
        assert!(r.ranked.windows(2).all(|w| w[0].1 <= w[1].1));
        assert_eq!(r.best().1, r.ranked[0].1);
    }

    #[test]
    fn locality_wins_the_high_node_small_size_corner() {
        let s = tiny();
        let r = s.lookup(Collective::Alltoallv, 32, 512).unwrap();
        assert_eq!(r.best().0, CollectiveAlgorithm::Locality);
        let r = s.lookup(Collective::Alltoallv, 2, 1 << 19).unwrap();
        assert_eq!(r.best().0, CollectiveAlgorithm::Standard);
    }

    #[test]
    fn off_lattice_queries_clamp_and_interpolate() {
        let s = tiny();
        // clamped extremes reproduce the corner cells
        let lo = s.lookup(Collective::Alltoall, 1, 1).unwrap();
        let corner = s.lookup(Collective::Alltoall, 2, 512).unwrap();
        assert_eq!(lo, corner);
        // interior sizes land within the bracketing envelope
        let mid = s.lookup(Collective::Alltoall, 4, 2048).unwrap();
        for (alg, t) in &mid.ranked {
            let k = s.algorithms.iter().position(|a| a == alg).unwrap();
            let (a, b) = (s.cells[s.index(0, 1, 0)][k], s.cells[s.index(0, 1, 1)][k]);
            let (lo, hi) = (a.min(b), a.max(b));
            assert!(*t >= lo * (1.0 - 1e-12) && *t <= hi * (1.0 + 1e-12), "{alg} {t} outside [{lo}, {hi}]");
        }
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(CollectiveSurface::compile("bogus", 4, vec![2], vec![512], 1).is_err());
        assert!(CollectiveSurface::compile("lassen", 3, vec![2], vec![512], 1).is_err());
        assert!(CollectiveSurface::compile("lassen", 4, vec![1, 2], vec![512], 1).is_err());
        assert!(CollectiveSurface::compile("lassen", 4, vec![2], vec![], 1).is_err());
        let mut s = tiny();
        s.cells.pop();
        assert!(s.validate().is_err());
    }
}
