//! # hetcomm
//!
//! Node-aware strategies for irregular point-to-point communication on
//! heterogeneous architectures — a full reproduction of Lockhart, Bienz,
//! Gropp & Olson (2022).
//!
//! The crate is organised in layers, bottom-up:
//!
//! - [`util`] — in-tree substrates (PRNG, CLI, config, stats, property
//!   testing) for the offline build environment.
//! - [`topology`] — machine descriptions (nodes, sockets, GPUs, NIC) for
//!   Lassen-like and exascale-like systems.
//! - [`params`] — the paper's measured modeling parameters (Tables 2–4):
//!   latency/bandwidth per locality and MPI protocol, memcpy costs, and the
//!   NIC injection-bandwidth limit, plus least-squares fitting.
//! - [`model`] — the closed-form performance models: postal (Eq. 2.1),
//!   max-rate (Eq. 2.2), on-node (4.1–4.2), off-node (4.3–4.4), copy (4.5)
//!   and the composite strategy models of Table 6.
//! - [`pattern`] — irregular communication patterns (who sends what to whom)
//!   and the scenario generators behind Figure 4.3.
//! - [`comm`] — the five communication strategies (Table 5) as message
//!   *schedule* generators: Standard, 3-Step, 2-Step, Split+MD, Split+DD,
//!   each staged-through-host and (where applicable) device-aware;
//!   Algorithms 1–2 live in [`comm::split`].
//! - [`sim`] — the discrete-event cluster simulator that stands in for the
//!   Lassen testbed: it executes schedules against the measured parameters,
//!   including max-rate NIC injection sharing. The hot path is compiled
//!   ([`sim::compiled`]): patterns are lowered once per cell, schedules into
//!   flat SoA arrays, and executed allocation-free against reusable
//!   scratch buffers (docs/PERFORMANCE.md).
//! - [`sparse`] — CSR/ELL sparse matrices, Matrix Market I/O, structured
//!   generators and SuiteSparse structural proxies, and the row-wise
//!   partitioner that induces the SpMV communication patterns.
//! - [`runtime`] — PJRT wrapper loading the AOT-compiled JAX/Pallas SpMV
//!   artifacts (HLO text) produced by `python/compile/aot.py`.
//! - [`coordinator`] — the leader/worker distributed SpMV engine: real data
//!   plane (bytes actually move between per-GPU workers), simulated clock
//!   (the paper's measured constants cost every transfer).
//! - [`sweep`] — the parallel strategy-sweep engine: the full
//!   (strategy × generator × nodes × GPUs × size) grid through models and
//!   simulator, with winner/crossover reporting (the `sweep` subcommand).
//! - [`collective`] — the locality-aware collective layer: alltoall /
//!   alltoallv / allgather synthesized as [`pattern::CommPattern`]s, the
//!   standard / pairwise / locality-aware algorithms lowered to staged
//!   per-phase patterns, costed by composing the Table 6 primitives and
//!   simulated end-to-end, with its own sweep grid, crossover report and
//!   compiled decision surfaces (the `collective` subcommand).
//! - [`advisor`] — the online strategy-advisor service: per-machine compiled
//!   decision surfaces (versioned JSON artifacts), a sharded LRU cache and
//!   batch serving layer, and measurement-driven recalibration (the
//!   `advise` subcommand and the coordinator's auto strategy mode).
//! - [`trace`] — trace-driven workload replay: versioned
//!   `hetcomm.trace.v1` recordings of per-iteration communication patterns,
//!   synthetic evolving scenarios (AMR drift, sparsification, rebalance,
//!   halo bursts), and a replay engine whose adaptive mode re-advises on
//!   pattern drift (the `replay` subcommand and `sweep --trace`).
//! - [`fault`] — seeded, deterministic fault/degradation injection:
//!   versioned `hetcomm.faults.v1` schedules of rail failures, bandwidth
//!   slowdowns and background congestion, degrading shapes and parameters
//!   and pre-charging simulator NIC timelines so adaptive replay is tested
//!   against *external* drift (`replay --faults`, `sweep --faults`).
//! - [`bench`] — the in-tree benchmark harness used by `rust/benches/*`,
//!   plus [`bench::perf`], the `hetcomm perf` self-benchmark harness behind
//!   the committed `BENCH_sweep.json` performance trajectory.

pub mod advisor;
pub mod bench;
pub mod collective;
pub mod comm;
pub mod coordinator;
pub mod fault;
pub mod model;
pub mod params;
pub mod pattern;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod sweep;
pub mod topology;
pub mod trace;
pub mod util;

pub use advisor::{AdvisorService, DecisionSurface};
pub use collective::{Collective, CollectiveAlgorithm, CollectiveSurface};
pub use fault::{FaultEvent, FaultKind, FaultSpec, FaultState};
pub use comm::{Schedule, Strategy, StrategyKind, Transport};
pub use params::{MachineParams, Protocol};
pub use pattern::CommPattern;
pub use sweep::{SweepConfig, SweepResult};
pub use topology::{Locality, Machine};
pub use trace::{Trace, TraceRecorder};
