//! `hetcomm` launcher — CLI front end over the library.
//!
//! Subcommands:
//! - `params`   — print the measured Lassen parameter tables (Tables 2–4);
//! - `model`    — evaluate the Table 6 models for a scenario (Figure 4.3);
//! - `sweep`    — sweep message sizes × strategies, model + simulator;
//! - `spmv`     — run the distributed SpMV benchmark on a matrix proxy;
//! - `validate` — compare model predictions against simulated SpMV
//!   communication (Figure 4.2);
//! - `e2e`      — run the end-to-end power iteration through PJRT.

use hetcomm::bench::{fmt_secs, Table};
use hetcomm::comm::{Strategy, StrategyKind, Transport};
use hetcomm::coordinator::{DistSpmv, SpmvConfig};
use hetcomm::model::StrategyModel;
use hetcomm::params::lassen_params;
use hetcomm::pattern::generators::Scenario;
use hetcomm::sparse::{suite, PartitionedMatrix};
use hetcomm::topology::machines;
use hetcomm::util::cli::Cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sub = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { &[] } else { &args[1..] };
    let code = match sub {
        "params" => cmd_params(),
        "model" => cmd_model(rest),
        "sweep" => cmd_sweep(rest),
        "spmv" => cmd_spmv(rest),
        "validate" => cmd_validate(rest),
        "study" => cmd_study(rest),
        "e2e" => cmd_e2e(rest),
        "help" | "--help" | "-h" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "hetcomm — node-aware irregular P2P communication on heterogeneous architectures

USAGE: hetcomm <SUBCOMMAND> [FLAGS]

SUBCOMMANDS:
  params     print the measured Lassen parameter tables (Tables 2-4)
  model      evaluate the Table 6 strategy models for a scenario
  sweep      sweep message sizes x strategies (model + simulator)
  spmv       distributed SpMV communication benchmark (SuiteSparse proxies)
  validate   model-vs-simulation comparison (Figure 4.2)
  study      Section 6 outlook: strategy winners on future machines
  e2e        end-to-end power iteration through the PJRT artifact
  help       this text

Run `hetcomm <SUBCOMMAND> --help` for flags."
    );
}

fn cmd_params() -> i32 {
    let p = lassen_params();
    let mut t = Table::new("Table 2 — inter-CPU / inter-GPU messaging parameters (Lassen)", &[
        "path", "protocol", "alpha[s]", "beta[s/B]",
    ]);
    use hetcomm::params::Protocol::*;
    use hetcomm::topology::Locality::*;
    for (proto, name) in [(Short, "short"), (Eager, "eager"), (Rendezvous, "rend")] {
        for loc in [OnSocket, OnNode, OffNode] {
            let ab = p.cpu_ab(proto, loc);
            t.row(vec![format!("CPU {loc}"), name.into(), format!("{:.2e}", ab.alpha), format!("{:.2e}", ab.beta)]);
        }
    }
    for (proto, name) in [(Eager, "eager"), (Rendezvous, "rend")] {
        for loc in [OnSocket, OnNode, OffNode] {
            let ab = p.gpu_ab(proto, loc);
            t.row(vec![format!("GPU {loc}"), name.into(), format!("{:.2e}", ab.alpha), format!("{:.2e}", ab.beta)]);
        }
    }
    t.print();

    let mut t3 = Table::new("Table 3 — cudaMemcpyAsync parameters", &["procs", "dir", "alpha[s]", "beta[s/B]"]);
    use hetcomm::params::CopyDir::*;
    for (np, label) in [(1usize, "1"), (4, "4")] {
        for (dir, dl) in [(H2D, "H2D"), (D2H, "D2H")] {
            let ab = p.memcpy_ab(dir, np);
            t3.row(vec![label.into(), dl.into(), format!("{:.2e}", ab.alpha), format!("{:.2e}", ab.beta)]);
        }
    }
    t3.print();
    println!("\nTable 4 — injection bandwidth: 1/R_N = {:.2e} s/B (R_N = {:.3e} B/s)", p.inv_rn, p.rn());
    0
}

fn cmd_model(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm model", "evaluate the Table 6 models for one scenario")
        .flag("msgs", "256", "inter-node messages from the sending node")
        .flag("size", "2048", "bytes per message")
        .flag("dest", "16", "destination node count")
        .flag("dup", "0.0", "duplicate-data fraction removed by node-aware strategies")
        .flag("nodes", "32", "cluster node count");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let machine = machines::lassen(a.get_usize("nodes").unwrap());
    let params = lassen_params();
    let sc = Scenario {
        n_msgs: a.get_usize("msgs").unwrap(),
        msg_size: a.get_usize("size").unwrap(),
        n_dest: a.get_usize("dest").unwrap(),
        dup_frac: a.get_f64("dup").unwrap(),
    };
    let inputs = sc.inputs(&machine, machine.cores_per_node());
    let sm = StrategyModel::new(&machine, &params);
    let mut t = Table::new(
        format!("Modeled time: {} msgs x {} B to {} nodes (dup {:.0}%)", sc.n_msgs, sc.msg_size, sc.n_dest, sc.dup_frac * 100.0),
        &["strategy", "modeled[s]"],
    );
    let mut best: Option<(String, f64)> = None;
    for (s, secs) in sm.all_times(&inputs) {
        t.row(vec![s.label(), fmt_secs(secs)]);
        if best.as_ref().map(|b| secs < b.1).unwrap_or(true) {
            best = Some((s.label(), secs));
        }
    }
    t.print();
    let (label, secs) = best.unwrap();
    println!("\nfastest: {label} ({})", fmt_secs(secs));
    0
}

fn cmd_sweep(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm sweep", "message-size sweep across strategies (model)")
        .flag("msgs", "256", "inter-node messages")
        .flag("dest", "16", "destination nodes")
        .flag("sizes", "2^4,2^6,2^8,2^10,2^12,2^14,2^16,2^18,2^20", "comma list of sizes (supports 2^k)")
        .flag("nodes", "32", "cluster nodes");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let machine = machines::lassen(a.get_usize("nodes").unwrap());
    let params = lassen_params();
    let sm = StrategyModel::new(&machine, &params);
    let strategies = Strategy::all();
    let mut header: Vec<String> = vec!["size[B]".into()];
    header.extend(strategies.iter().map(|s| s.label()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Model sweep", &hdr);
    for size in a.get_usize_list("sizes").unwrap() {
        let sc = Scenario {
            n_msgs: a.get_usize("msgs").unwrap(),
            msg_size: size,
            n_dest: a.get_usize("dest").unwrap(),
            dup_frac: 0.0,
        };
        let inputs = sc.inputs(&machine, machine.cores_per_node());
        let mut row = vec![size.to_string()];
        row.extend(strategies.iter().map(|&s| fmt_secs(sm.time(s, &inputs))));
        t.row(row);
    }
    t.print();
    0
}

fn cmd_spmv(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm spmv", "distributed SpMV communication benchmark")
        .flag("matrix", "audikw_1", "SuiteSparse matrix name (proxy)")
        .flag("scale", "64", "row divisor for the proxy")
        .flag("gpus", "8", "partition count")
        .flag("nodes", "2", "cluster nodes")
        .flag("iters", "3", "repetitions")
        .switch("pjrt", "run local compute through the PJRT artifact");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let Some(info) = suite::info(a.get("matrix")) else {
        eprintln!("unknown matrix {:?}; known: {:?}", a.get("matrix"), suite::MATRICES.map(|m| m.name));
        return 2;
    };
    let mat = suite::proxy(info, a.get_usize("scale").unwrap());
    let machine = machines::lassen(a.get_usize("nodes").unwrap());
    let gpus = a.get_usize("gpus").unwrap();
    println!("matrix {} proxy: {} rows, {} nnz over {gpus} GPUs", info.name, mat.nrows, mat.nnz());

    let mut v = vec![0f32; mat.nrows];
    for (i, x) in v.iter_mut().enumerate() {
        *x = ((i % 17) as f32 - 8.0) / 8.0;
    }
    let cfg = SpmvConfig { use_pjrt: a.get_bool("pjrt"), ..Default::default() };
    let mut t = Table::new(
        format!("SpMV comm: {} ({} GPUs)", info.name, gpus),
        &["strategy", "sim[s]", "wall-ex[s]", "msgs", "verified"],
    );
    for s in Strategy::all().into_iter().filter(|s| s.transport == Transport::Staged || s.kind != StrategyKind::Standard) {
        // Data-plane execution is transport-agnostic; run each kind once
        // (staged) and report the simulated time for the exact transport.
        if s.transport == Transport::DeviceAware {
            continue;
        }
        match DistSpmv::new(&mat, gpus, &machine, s, cfg.clone()) {
            Ok(d) => match d.run(&v, a.get_usize("iters").unwrap()) {
                Ok(rep) => t.row(vec![
                    s.label(),
                    fmt_secs(rep.sim_exchange_per_iter),
                    fmt_secs(rep.wall_exchange),
                    rep.msgs_per_iter.to_string(),
                    format!("{:?}", rep.verified),
                ]),
                Err(e) => t.row(vec![s.label(), format!("run error: {e}"), String::new(), String::new(), String::new()]),
            },
            Err(e) => t.row(vec![s.label(), format!("setup error: {e}"), String::new(), String::new(), String::new()]),
        }
    }
    t.print();
    0
}

fn cmd_validate(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm validate", "model vs simulated SpMV communication (Figure 4.2)")
        .flag("scale", "64", "proxy scale")
        .flag("gpus", "16", "partition count")
        .flag("nodes", "4", "cluster nodes");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let info = suite::info("audikw_1").unwrap();
    let mat = suite::proxy(info, a.get_usize("scale").unwrap());
    let machine = machines::lassen(a.get_usize("nodes").unwrap());
    let params = lassen_params();
    let gpus = a.get_usize("gpus").unwrap();
    let pm = PartitionedMatrix::build(&mat, gpus);
    let pattern = pm.comm_pattern(&machine, 8);
    let dup = pattern.duplicate_fraction(&machine);
    let sm = StrategyModel::new(&machine, &params);

    let mut t = Table::new(
        format!("Model validation: audikw_1 proxy on {gpus} GPUs (dup {:.1}%)", dup * 100.0),
        &["strategy", "model[s]", "simulated[s]", "ratio"],
    );
    for s in Strategy::all() {
        let ppn = match s.kind {
            StrategyKind::SplitMd | StrategyKind::SplitDd => machine.cores_per_node(),
            _ => machine.gpus_per_node(),
        };
        let inputs = pattern.model_inputs(&machine, ppn, dup);
        let model = sm.time(s, &inputs);
        let sched = hetcomm::comm::build_schedule(s, &machine, &pattern);
        let simd = hetcomm::sim::run(&machine, &params, &sched, ppn).total;
        t.row(vec![s.label(), fmt_secs(model), fmt_secs(simd), format!("{:.2}", model / simd)]);
    }
    t.print();
    0
}

fn cmd_study(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm study", "Section 6 outlook: best strategy on current and future machines")
        .flag("msgs", "256", "inter-node messages per node")
        .flag("dest", "16", "destination nodes")
        .flag("machine", "all", "lassen | frontier | delta | all")
        .flag("bw-scale", "0", "interconnect bandwidth multiplier (0 = per-machine default)")
        .flag("sizes", "2^8,2^10,2^12,2^14,2^16,2^18", "message sizes");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let base = lassen_params();
    let chosen = a.get("machine");
    let bw_override = a.get_f64("bw-scale").unwrap();
    let mut configs: Vec<(&str, hetcomm::Machine, hetcomm::MachineParams)> = Vec::new();
    if chosen == "all" || chosen == "lassen" {
        configs.push(("lassen", machines::lassen(32), base.clone()));
    }
    if chosen == "all" || chosen == "frontier" {
        let bw = if bw_override > 0.0 { bw_override } else { 4.0 };
        configs.push(("frontier-like", machines::frontier_like(32), base.scaled(0.8, bw)));
    }
    if chosen == "all" || chosen == "delta" {
        let bw = if bw_override > 0.0 { bw_override } else { 2.0 };
        configs.push(("delta-like", machines::delta_like(32), base.scaled(1.0, bw)));
    }
    if configs.is_empty() {
        eprintln!("unknown machine {chosen:?}");
        return 2;
    }
    let mut t = Table::new(
        format!("Section 6 study — {} msgs to {} nodes", a.get("msgs"), a.get("dest")),
        &["machine", "cores/node", "size[B]", "best strategy", "modeled[s]"],
    );
    for (name, machine, params) in &configs {
        let sm = StrategyModel::new(machine, params);
        for size in a.get_usize_list("sizes").unwrap() {
            let sc = Scenario {
                n_msgs: a.get_usize("msgs").unwrap(),
                msg_size: size,
                n_dest: a.get_usize("dest").unwrap(),
                dup_frac: 0.0,
            };
            let inputs = sc.inputs(machine, machine.cores_per_node());
            let (best, secs) = sm.best(&inputs);
            t.row(vec![
                name.to_string(),
                machine.cores_per_node().to_string(),
                size.to_string(),
                best.label(),
                fmt_secs(secs),
            ]);
        }
    }
    t.print();
    0
}

fn cmd_e2e(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm e2e", "end-to-end power iteration through PJRT")
        .flag("side", "8", "stencil cube side (rows = side^3)")
        .flag("gpus", "8", "partition count")
        .flag("nodes", "2", "cluster nodes")
        .flag("iters", "20", "power iterations")
        .flag("artifacts", "artifacts", "artifact directory")
        .switch("no-pjrt", "use the in-Rust kernel instead of PJRT");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let side = a.get_usize("side").unwrap();
    // 2x depth keeps per-part slabs >= 2 layers thick so the offd block
    // fits the artifact's static ELL width.
    let mat = hetcomm::sparse::gen::stencil_27pt(side, side, 2 * side);
    let machine = machines::lassen(a.get_usize("nodes").unwrap());
    let cfg = SpmvConfig {
        use_pjrt: !a.get_bool("no-pjrt"),
        artifacts_dir: a.get("artifacts").into(),
        ..Default::default()
    };
    let strategy = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();
    let d = match DistSpmv::new(&mat, a.get_usize("gpus").unwrap(), &machine, strategy, cfg) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("setup failed: {e:#}");
            return 1;
        }
    };
    let v0 = vec![1f32; mat.nrows];
    match d.power_iterate(&v0, a.get_usize("iters").unwrap()) {
        Ok((_, lambda, t_ex, t_cp)) => {
            println!("power iteration converged: lambda={lambda:.4} exchange={t_ex:.4}s compute={t_cp:.4}s");
            println!("sim exchange/iter: {}", fmt_secs(d.sim_report.total));
            0
        }
        Err(e) => {
            eprintln!("e2e failed: {e:#}");
            1
        }
    }
}
