//! `hetcomm` launcher — CLI front end over the library.
//!
//! Subcommands:
//! - `params`   — print the measured Lassen parameter tables (Tables 2–4);
//! - `model`    — evaluate the Table 6 models for a scenario (Figure 4.3);
//! - `sweep`    — parallel strategy sweep: the full (strategy × generator ×
//!   nodes × GPUs × size) grid through models + simulator, with winner,
//!   crossover and regime reporting (JSON / CSV / table);
//! - `collective` — the locality-aware collective layer: alltoall /
//!   alltoallv / allgather lowered to staged phase patterns under the
//!   standard / pairwise / locality algorithms, modeled from the Table 6
//!   primitives and simulated end-to-end over a seeded grid, with winner /
//!   crossover / regime reporting and compiled collective surfaces;
//! - `advise`   — the online strategy advisor: compile decision surfaces
//!   (JSON or the quantized `--quant` v3 encoding), answer snapshot-served
//!   queries, run the seeded burst benchmark (optionally over a multi-tenant
//!   machine fleet), recalibrate;
//! - `replay`   — trace-driven workload replay: synthesize / record / load
//!   evolving communication traces and replay them under static or
//!   drift-adaptive strategy policies;
//! - `spmv`     — run the distributed SpMV benchmark on a matrix proxy;
//! - `perf`     — the hot-path self-benchmark harness: seeded, deterministic
//!   throughput measurements in two suites (`--suite sweep`: cells/sec,
//!   schedules/sec; `--suite advise`: the serving engine's burst / miss /
//!   batch / publish legs) emitted as a versioned `hetcomm.bench.v1`
//!   artifact, with baseline comparison against the committed
//!   `BENCH_sweep.json` / `BENCH_advise.json` trajectories;
//! - `validate` — compare model predictions against simulated SpMV
//!   communication (Figure 4.2);
//! - `e2e`      — run the end-to-end power iteration through PJRT.

use hetcomm::bench::{fmt_secs, Table};
use hetcomm::comm::{Strategy, StrategyKind, Transport};
use hetcomm::coordinator::{DistSpmv, SpmvConfig};
use hetcomm::model::StrategyModel;
use hetcomm::params::lassen_params;
use hetcomm::pattern::generators::Scenario;
use hetcomm::sparse::{suite, PartitionedMatrix};
use hetcomm::topology::machines;
use hetcomm::util::cli::Cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sub = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { &[] } else { &args[1..] };
    let code = match sub {
        "params" => cmd_params(),
        "model" => cmd_model(rest),
        "sweep" => cmd_sweep(rest),
        "collective" => cmd_collective(rest),
        "advise" => cmd_advise(rest),
        "replay" => cmd_replay(rest),
        "spmv" => cmd_spmv(rest),
        "perf" => cmd_perf(rest),
        "validate" => cmd_validate(rest),
        "study" => cmd_study(rest),
        "e2e" => cmd_e2e(rest),
        "help" | "--help" | "-h" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

/// Surface explicitly-given flags a branch ignores instead of silently
/// dropping them: one `note:` line per present flag, phrased by `msg`.
/// Shared by the `--tiny` smoke grids, the trace reroute and the
/// collective-axis reroute, so every branch reports the same way.
fn note_ignored_flags(argv: &[String], flags: &[&str], msg: impl Fn(&str) -> String) {
    for &flag in flags {
        if argv.iter().any(|t| t == flag || t.starts_with(&format!("{flag}="))) {
            eprintln!("note: {}", msg(flag));
        }
    }
}

fn print_help() {
    println!(
        "hetcomm — node-aware irregular P2P communication on heterogeneous architectures

USAGE: hetcomm <SUBCOMMAND> [FLAGS]

SUBCOMMANDS:
  params     print the measured Lassen parameter tables (Tables 2-4)
  model      evaluate the Table 6 strategy models for a scenario
  sweep      parallel strategy sweep over the full characterization grid
  collective locality-aware alltoall/alltoallv/allgather: model + simulate algorithms
  advise     online strategy advisor: compile / query / bench-burst / recalibrate
  replay     trace-driven workload replay: record / synthesize / adapt online
  spmv       distributed SpMV communication benchmark (SuiteSparse proxies)
  perf       hot-path self-benchmark: seeded throughput report + baseline compare
  validate   model-vs-simulation comparison (Figure 4.2)
  study      Section 6 outlook: strategy winners on future machines
  e2e        end-to-end power iteration through the PJRT artifact
  help       this text

Run `hetcomm <SUBCOMMAND> --help` for flags."
    );
}

fn cmd_params() -> i32 {
    let p = lassen_params();
    let mut t = Table::new("Table 2 — inter-CPU / inter-GPU messaging parameters (Lassen)", &[
        "path", "protocol", "alpha[s]", "beta[s/B]",
    ]);
    use hetcomm::params::Protocol::*;
    use hetcomm::topology::Locality::*;
    for (proto, name) in [(Short, "short"), (Eager, "eager"), (Rendezvous, "rend")] {
        for loc in [OnSocket, OnNode, OffNode] {
            let ab = p.cpu_ab(proto, loc);
            t.row(vec![format!("CPU {loc}"), name.into(), format!("{:.2e}", ab.alpha), format!("{:.2e}", ab.beta)]);
        }
    }
    for (proto, name) in [(Eager, "eager"), (Rendezvous, "rend")] {
        for loc in [OnSocket, OnNode, OffNode] {
            let ab = p.gpu_ab(proto, loc);
            t.row(vec![format!("GPU {loc}"), name.into(), format!("{:.2e}", ab.alpha), format!("{:.2e}", ab.beta)]);
        }
    }
    t.print();

    let mut t3 = Table::new("Table 3 — cudaMemcpyAsync parameters", &["procs", "dir", "alpha[s]", "beta[s/B]"]);
    use hetcomm::params::CopyDir::*;
    for (np, label) in [(1usize, "1"), (4, "4")] {
        for (dir, dl) in [(H2D, "H2D"), (D2H, "D2H")] {
            let ab = p.memcpy_ab(dir, np);
            t3.row(vec![label.into(), dl.into(), format!("{:.2e}", ab.alpha), format!("{:.2e}", ab.beta)]);
        }
    }
    t3.print();
    println!("\nTable 4 — injection bandwidth: 1/R_N = {:.2e} s/B (R_N = {:.3e} B/s)", p.inv_rn, p.rn());
    0
}

fn cmd_model(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm model", "evaluate the Table 6 models for one scenario")
        .flag("msgs", "256", "inter-node messages from the sending node")
        .flag("size", "2048", "bytes per message")
        .flag("dest", "16", "destination node count")
        .flag("dup", "0.0", "duplicate-data fraction removed by node-aware strategies")
        .flag("nodes", "32", "cluster node count")
        .flag("nics", "0", "NIC rails per node (0 = machine preset default)")
        .flag("machine", "lassen", "machine preset (lassen | summit | frontier-like | frontier-4nic | delta-like)");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let (machine, params) = match machines::parse(a.get("machine"), a.get_usize("nodes").unwrap()) {
        Ok(mp) => mp,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let nics = a.get_usize("nics").unwrap();
    let machine = if nics > 0 {
        // same policy as `sweep` / `advise`: a pinned shape rejects any
        // explicit override, even the matching value
        if machines::shape_pinned(&machine.name) {
            eprintln!(
                "--nics conflicts with machine {:?}, whose shape pins {} NICs/node",
                machine.name,
                machine.nics_per_node()
            );
            return 2;
        }
        machines::with_shape_nics(&machine, machine.num_nodes, machine.gpus_per_node(), nics)
    } else {
        machine
    };
    let sc = Scenario {
        n_msgs: a.get_usize("msgs").unwrap(),
        msg_size: a.get_usize("size").unwrap(),
        n_dest: a.get_usize("dest").unwrap(),
        dup_frac: a.get_f64("dup").unwrap(),
    };
    let inputs = sc.inputs(&machine, machine.cores_per_node());
    let sm = StrategyModel::new(&machine, &params);
    let rails = if machine.nics_per_node() > 1 {
        format!(", {} NICs/node", machine.nics_per_node())
    } else {
        String::new()
    };
    let mut t = Table::new(
        format!(
            "Modeled time: {} msgs x {} B to {} nodes (dup {:.0}%{rails})",
            sc.n_msgs,
            sc.msg_size,
            sc.n_dest,
            sc.dup_frac * 100.0
        ),
        &["strategy", "modeled[s]"],
    );
    let mut best: Option<(&'static str, f64)> = None;
    for (s, secs) in sm.all_times(&inputs) {
        t.row(vec![s.label().to_string(), fmt_secs(secs)]);
        if best.as_ref().map(|b| secs < b.1).unwrap_or(true) {
            best = Some((s.label(), secs));
        }
    }
    t.print();
    let (label, secs) = best.unwrap();
    println!("\nfastest: {label} ({})", fmt_secs(secs));
    0
}

/// Parse `--strategies`: "all" or a comma list of kind names; each kind
/// expands to its valid Table 5 transports.
fn parse_strategies(spec: &str) -> Result<Vec<Strategy>, String> {
    if spec.trim().eq_ignore_ascii_case("all") {
        return Ok(Strategy::all());
    }
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let kind = StrategyKind::parse(part)
            .ok_or_else(|| format!("unknown strategy kind {part:?} (standard, 3-step, 2-step, split-md, split-dd)"))?;
        out.push(Strategy::new(kind, Transport::Staged).expect("staged always valid"));
        if kind.supports_device_aware() {
            out.push(Strategy::new(kind, Transport::DeviceAware).expect("checked"));
        }
    }
    if out.is_empty() {
        return Err("empty strategy list".into());
    }
    Ok(out)
}

/// Render a sweep result in `format` and deliver it to `out_path`
/// (`'-'` = stdout). Shared by the grid and trace sweep paths. Returns the
/// process exit code (0 on success).
fn emit_sweep_result(result: &hetcomm::sweep::SweepResult, format: &str, out_path: &str) -> i32 {
    let body = match format {
        "json" => hetcomm::sweep::emit::to_json(result),
        "csv" => hetcomm::sweep::emit::to_csv(result),
        "table" => hetcomm::sweep::emit::render_tables(result),
        other => {
            eprintln!("unknown format {other:?} (table | json | csv)");
            return 2;
        }
    };
    if out_path == "-" {
        print!("{body}");
    } else if let Err(e) = std::fs::write(out_path, &body) {
        eprintln!("cannot write {out_path}: {e}");
        return 1;
    }
    0
}

fn cmd_sweep(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm sweep", "parallel strategy sweep: model + simulator over the full grid")
        .flag("msgs", "256", "inter-node messages per scenario")
        .flag("dest", "4,8,16", "destination-node counts (comma list)")
        .flag("gpn", "4", "GPUs per node (comma list, even values)")
        .flag("nics", "1", "NIC rails per node (comma list; the §6 shape axis)")
        .flag("sizes", "2^4,2^6,2^8,2^10,2^12,2^14,2^16,2^18,2^20", "message sizes (supports 2^k)")
        .flag("dup", "0.0", "duplicate-data fraction in [0,1)")
        .flag("gens", "uniform,random", "pattern generators (uniform|random)")
        .flag("strategies", "all", "strategy kinds (comma list) or 'all'")
        .flag("seed", "42", "base seed for per-cell generators")
        .flag("threads", "0", "worker threads (0 = all cores)")
        .flag("format", "table", "output format: table | json | csv")
        .flag("out", "-", "output path ('-' = stdout)")
        .flag("machine", "lassen", "machine preset (lassen | summit | frontier-like | frontier-4nic | delta-like)")
        .flag("emit-surface", "", "also compile the grid into an advisor surface artifact at this path")
        .flag("trace", "", "sweep a recorded hetcomm.trace.v1 workload instead of the grid (epoch = cell)")
        .flag("collectives", "", "grow a collective axis: sweep the locality-aware collective layer (comma list or 'all')")
        .flag("algorithms", "all", "with --collectives: algorithms (standard | pairwise | locality) or 'all'")
        .flag("nodes", "2,8,32", "with --collectives: cluster node counts (comma list, >= 2)")
        .flag("refine", "0", "adaptive (nodes x size) boundary refinement depth (0 = exhaustive; winners preserved)")
        .flag("faults", "", "sweep the degraded fleet: apply a hetcomm.faults.v1 schedule's terminal state to every cell")
        .switch("tiny", "run the <10s smoke grid instead of the flag-defined grid")
        .switch("model-only", "skip the discrete-event simulator")
        .switch("prune", "skip simulating strategies whose model lower bound exceeds the cell incumbent")
        .switch("reuse-patterns", "share one pattern lowering across each uniform grid line's size axis");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };

    // Fault schedules degrade the *strategy grid*; the collective axis and
    // trace sweeps have their own machines (use `replay --faults` for the
    // epoch-resolved story on a trace).
    if !a.get("faults").is_empty() && (!a.get("collectives").is_empty() || !a.get("trace").is_empty()) {
        eprintln!("--faults degrades the strategy grid; for traces use `hetcomm replay --faults` (epoch-resolved)");
        return 2;
    }

    // Collective-axis sweep: --collectives reroutes the grid to the
    // locality-aware collective layer. Grids without the axis take the
    // legacy path below and emit byte-identical output.
    if !a.get("collectives").is_empty() {
        // --prune and --refine are NOT in this list: both levers apply to
        // the collective grid too and thread straight through.
        let grid_flags =
            ["--msgs", "--dest", "--gens", "--dup", "--nics", "--strategies", "--trace", "--reuse-patterns"];
        note_ignored_flags(argv, &grid_flags, |flag| {
            format!("{flag} shapes the strategy grid; the collective axis ignores it")
        });
        return run_collective_grid(&a, argv);
    }

    // Trace-sourced sweep: the recorded epochs replace the generated grid,
    // and the trace's own recorded machine replaces --machine.
    if !a.get("trace").is_empty() {
        let trace = match hetcomm::trace::persist::load(a.get("trace")) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("cannot load trace: {e}");
                return 2;
            }
        };
        if argv.iter().any(|t| t == "--machine" || t.starts_with("--machine=")) {
            eprintln!("note: sweeping the trace on its recorded machine {:?} (--machine ignored)", trace.machine.name);
        }
        let grid_flags = [
            "--msgs", "--dest", "--gpn", "--nics", "--sizes", "--dup", "--gens", "--seed", "--tiny", "--prune",
            "--reuse-patterns", "--refine",
        ];
        note_ignored_flags(argv, &grid_flags, |flag| {
            format!("{flag} shapes the generated grid; trace epochs are replayed verbatim (ignored)")
        });
        let strategies = match parse_strategies(a.get("strategies")) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let threads = match a.get_usize("threads") {
            Ok(t) => t,
            Err(e) => {
                eprintln!("{}", e.0);
                return 2;
            }
        };
        let result = match hetcomm::sweep::run_sweep_trace(&trace, &strategies, threads, !a.get_bool("model-only")) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("trace sweep failed: {e}");
                return 2;
            }
        };
        let code = emit_sweep_result(&result, a.get("format"), a.get("out"));
        if code != 0 {
            return code;
        }
        eprintln!(
            "swept {} trace epochs x {} strategies on {} threads in {:.3}s",
            trace.epochs.len(),
            strategies.len(),
            result.threads_used,
            result.elapsed_s
        );
        if !a.get("emit-surface").is_empty() {
            eprintln!("note: --emit-surface needs a grid sweep (trace epochs define no lattice axes); skipped");
        }
        return 0;
    }

    let grid = if a.get_bool("tiny") {
        // the smoke grid is fixed; surface explicitly-given grid flags
        // instead of silently dropping them (mirrors the --trace branch)
        note_ignored_flags(argv, &["--msgs", "--dest", "--gpn", "--nics", "--sizes", "--dup", "--gens"], |flag| {
            format!("--tiny runs the fixed smoke grid; {flag} is ignored")
        });
        hetcomm::sweep::GridSpec::tiny()
    } else {
        let mut gens = Vec::new();
        for part in a.get("gens").split(',').filter(|p| !p.trim().is_empty()) {
            match hetcomm::sweep::PatternGen::parse(part) {
                Some(g) => gens.push(g),
                None => {
                    eprintln!("unknown pattern generator {part:?} (uniform | random)");
                    return 2;
                }
            }
        }
        hetcomm::sweep::GridSpec {
            gens,
            dest_nodes: match a.get_usize_list("dest") {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{}", e.0);
                    return 2;
                }
            },
            gpus_per_node: match a.get_usize_list("gpn") {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{}", e.0);
                    return 2;
                }
            },
            nics: match a.get_usize_list("nics") {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{}", e.0);
                    return 2;
                }
            },
            sizes: match a.get_usize_list("sizes") {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{}", e.0);
                    return 2;
                }
            },
            n_msgs: match a.get_usize("msgs") {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{}", e.0);
                    return 2;
                }
            },
            dup_frac: match a.get_f64("dup") {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{}", e.0);
                    return 2;
                }
            },
        }
    };

    // A preset whose shape pins the NIC count *is* the node description:
    // an explicit --nics (even the matching value) is a contradiction the
    // engine cannot see, so reject it here where "explicit" is knowable.
    let nics_given = argv.iter().any(|t| t == "--nics" || t.starts_with("--nics="));
    if nics_given && machines::shape_pinned(a.get("machine")) {
        eprintln!("--nics cannot override machine {:?}: its shape pins the NIC count", a.get("machine"));
        return 2;
    }

    let strategies = match parse_strategies(a.get("strategies")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let (seed, threads) = match (a.get_u64("seed"), a.get_usize("threads")) {
        (Ok(s), Ok(t)) => (s, t),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let refine = match a.get_usize("refine") {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let faults = if a.get("faults").is_empty() {
        None
    } else {
        match hetcomm::fault::persist::load(a.get("faults")) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("cannot load fault spec: {e}");
                return 2;
            }
        }
    };
    let config = hetcomm::sweep::SweepConfig {
        grid,
        strategies,
        seed,
        threads,
        sim: !a.get_bool("model-only"),
        machine: a.get("machine").to_string(),
        prune: a.get_bool("prune"),
        reuse_patterns: a.get_bool("reuse-patterns"),
        refine,
        faults,
    };

    let result = match hetcomm::sweep::run_sweep(&config) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("sweep failed: {e}");
            return 2;
        }
    };

    let code = emit_sweep_result(&result, a.get("format"), a.get("out"));
    if code != 0 {
        return code;
    }
    eprintln!(
        "swept {} grid cells x {} strategies on {} threads in {:.3}s",
        result.cells.len() / config.strategies.len().max(1),
        config.strategies.len(),
        result.threads_used,
        result.elapsed_s
    );

    // Emit the surface LAST: a bad artifact path must not discard the
    // sweep results above.
    let surface_path = a.get("emit-surface");
    if !surface_path.is_empty() {
        if result.config.faults.is_some() {
            eprintln!("note: surfaces describe the healthy machine; --emit-surface under --faults is skipped");
            return 0;
        }
        if config.strategies.len() != Strategy::all().len() {
            eprintln!("note: surface artifacts always cover all Table 5 strategies (--strategies filter not baked in)");
        }
        if result.config.grid.nics.len() != 1 {
            eprintln!("note: surfaces are keyed by one node shape; --emit-surface needs one --nics value (skipped)");
            return 0;
        }
        let axes = hetcomm::advisor::SurfaceAxes {
            msgs: vec![config.grid.n_msgs],
            sizes: config.grid.sizes.clone(),
            dest_nodes: config.grid.dest_nodes.clone(),
            gpus_per_node: config.grid.gpus_per_node.clone(),
        };
        // pinned machines carry their own rail count (0 = preset default);
        // everything else keys the surface by the resolved grid axis
        let nics = if machines::shape_pinned(&config.machine) { 0 } else { result.config.grid.nics[0] };
        let compiled =
            hetcomm::advisor::DecisionSurface::compile_shaped(&config.machine, nics, axes, config.grid.dup_frac)
                .and_then(|s| hetcomm::advisor::persist::save(&s, surface_path));
        if let Err(e) = compiled {
            eprintln!("cannot emit surface: {e}");
            return 1;
        }
        eprintln!("wrote advisor surface artifact to {surface_path}");
    }
    0
}

/// Parse `--collectives`: "all" or a comma list of collective names.
fn parse_collectives(spec: &str) -> Result<Vec<hetcomm::Collective>, String> {
    if spec.trim().eq_ignore_ascii_case("all") {
        return Ok(hetcomm::Collective::ALL.to_vec());
    }
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let c = hetcomm::Collective::parse(part)
            .ok_or_else(|| format!("unknown collective {part:?} (alltoall | alltoallv | allgather)"))?;
        if !out.contains(&c) {
            out.push(c);
        }
    }
    if out.is_empty() {
        return Err("empty collective list".into());
    }
    Ok(out)
}

/// Parse `--algorithms`: "all" or a comma list of algorithm names.
fn parse_col_algorithms(spec: &str) -> Result<Vec<hetcomm::CollectiveAlgorithm>, String> {
    if spec.trim().eq_ignore_ascii_case("all") {
        return Ok(hetcomm::CollectiveAlgorithm::ALL.to_vec());
    }
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let a = hetcomm::CollectiveAlgorithm::parse(part)
            .ok_or_else(|| format!("unknown collective algorithm {part:?} (standard | pairwise | locality)"))?;
        if !out.contains(&a) {
            out.push(a);
        }
    }
    if out.is_empty() {
        return Err("empty collective algorithm list".into());
    }
    Ok(out)
}

/// Render a collective sweep result in `format` and deliver it to
/// `out_path` (`'-'` = stdout). Returns the process exit code.
fn emit_collective_result(result: &hetcomm::collective::CollectiveResult, format: &str, out_path: &str) -> i32 {
    let body = match format {
        "json" => hetcomm::collective::emit::to_json(result),
        "csv" => hetcomm::collective::emit::to_csv(result),
        "table" => hetcomm::collective::emit::render_tables(result),
        other => {
            eprintln!("unknown format {other:?} (table | json | csv)");
            return 2;
        }
    };
    if out_path == "-" {
        print!("{body}");
    } else if let Err(e) = std::fs::write(out_path, &body) {
        eprintln!("cannot write {out_path}: {e}");
        return 1;
    }
    0
}

/// The shared body of `hetcomm collective` and `hetcomm sweep
/// --collectives ...`: build the grid from the parsed flags, run it, emit,
/// and optionally compile a collective surface artifact.
fn run_collective_grid(a: &hetcomm::util::cli::Args, argv: &[String]) -> i32 {
    use hetcomm::collective as col;
    let grid = if a.get_bool("tiny") {
        note_ignored_flags(argv, &["--collectives", "--algorithms", "--nodes", "--gpn", "--sizes"], |flag| {
            format!("--tiny runs the fixed smoke grid; {flag} is ignored")
        });
        col::CollectiveGrid::tiny()
    } else {
        let collectives = match parse_collectives(a.get("collectives")) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let algorithms = match parse_col_algorithms(a.get("algorithms")) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let axes = (a.get_usize_list("nodes"), a.get_usize_list("gpn"), a.get_usize_list("sizes"));
        let (nodes, gpus_per_node, sizes) = match axes {
            (Ok(n), Ok(g), Ok(s)) => (n, g, s),
            (Err(e), ..) | (_, Err(e), _) | (.., Err(e)) => {
                eprintln!("{}", e.0);
                return 2;
            }
        };
        col::CollectiveGrid { collectives, algorithms, nodes, gpus_per_node, sizes }
    };
    let (seed, threads) = match (a.get_u64("seed"), a.get_usize("threads")) {
        (Ok(s), Ok(t)) => (s, t),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let refine = match a.get_usize("refine") {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let config = col::CollectiveConfig {
        grid,
        seed,
        threads,
        sim: !a.get_bool("model-only"),
        machine: a.get("machine").to_string(),
        prune: a.get_bool("prune"),
        refine,
    };
    let result = match col::run_collective(&config) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("collective sweep failed: {e}");
            return 2;
        }
    };
    let code = emit_collective_result(&result, a.get("format"), a.get("out"));
    if code != 0 {
        return code;
    }
    eprintln!(
        "swept {} collective cells -> {} algorithm rows on {} threads in {:.3}s",
        result.cells.last().map(|c| c.index + 1).unwrap_or(0),
        result.cells.len(),
        result.threads_used,
        result.elapsed_s
    );

    // Emit the surface LAST: a bad artifact path must not discard the
    // sweep results above (same policy as `sweep --emit-surface`).
    let surface_path = a.get("emit-surface");
    if !surface_path.is_empty() {
        if config.grid.gpus_per_node.len() != 1 {
            eprintln!("note: collective surfaces pin one GPUs/node value; --emit-surface needs one --gpn (skipped)");
            return 0;
        }
        if config.grid.collectives.len() != hetcomm::Collective::ALL.len()
            || config.grid.algorithms.len() != hetcomm::CollectiveAlgorithm::ALL.len()
        {
            eprintln!("note: surface artifacts always cover all collectives and algorithms (filters not baked in)");
        }
        let compiled = col::CollectiveSurface::compile(
            &config.machine,
            config.grid.gpus_per_node[0],
            config.grid.nodes.clone(),
            config.grid.sizes.clone(),
            config.seed,
        )
        .and_then(|s| col::persist::save(&s, surface_path));
        if let Err(e) = compiled {
            eprintln!("cannot emit collective surface: {e}");
            return 1;
        }
        eprintln!("wrote collective surface artifact to {surface_path}");
    }
    0
}

fn cmd_collective(argv: &[String]) -> i32 {
    let cli = Cli::new(
        "hetcomm collective",
        "locality-aware collectives: synthesize, lower, and model + simulate algorithms over a grid",
    )
    .flag("collectives", "all", "collectives to sweep (alltoall | alltoallv | allgather, comma list) or 'all'")
    .flag("algorithms", "all", "algorithms to compare (standard | pairwise | locality, comma list) or 'all'")
    .flag("nodes", "2,8,32", "cluster node counts (comma list, >= 2)")
    .flag("gpn", "4", "GPUs per node (comma list, even values)")
    .flag("sizes", "2^9,2^11,2^13,2^15,2^17,2^19", "block sizes in bytes (supports 2^k)")
    .flag("seed", "42", "base seed (fixes alltoallv's irregular per-pair block sizes)")
    .flag("threads", "0", "worker threads (0 = all cores)")
    .flag("format", "table", "output format: table | json | csv")
    .flag("out", "-", "output path ('-' = stdout)")
    .flag("machine", "lassen", "machine preset (lassen | summit | frontier-like | frontier-4nic | delta-like)")
    .flag("emit-surface", "", "also compile the node/size axes into a collective surface artifact at this path")
    .flag("refine", "0", "adaptive (nodes x size) boundary refinement depth (0 = exhaustive; winners preserved)")
    .switch("tiny", "run the fixed sub-second smoke grid instead of the flag-defined grid")
    .switch("model-only", "skip the discrete-event simulator")
    .switch("prune", "skip simulating algorithms whose bound-model lower bound exceeds the cell incumbent");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    run_collective_grid(&a, argv)
}

/// Parse the advise lattice axis flags into surface axes.
fn advise_axes_from(a: &hetcomm::util::cli::Args) -> Result<hetcomm::advisor::SurfaceAxes, String> {
    Ok(hetcomm::advisor::SurfaceAxes {
        msgs: a.get_usize_list("msgs").map_err(|e| e.0)?,
        sizes: a.get_usize_list("sizes").map_err(|e| e.0)?,
        dest_nodes: a.get_usize_list("dest").map_err(|e| e.0)?,
        gpus_per_node: a.get_usize_list("gpn").map_err(|e| e.0)?,
    })
}

/// Run the seeded burst against a service (one tenant or a fleet), print
/// the report, and enforce `--min-hit-rate`. Returns the exit code.
fn run_advise_burst(
    service: &hetcomm::advisor::AdvisorService,
    n: usize,
    seed: u64,
    threads: usize,
    min_hit_rate: f64,
) -> i32 {
    let report = match service.bench_burst(n, seed, threads) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("burst failed: {e}");
            return 1;
        }
    };
    println!(
        "burst: {} queries ({} distinct patterns) on {} threads in {:.3}s",
        report.queries, report.distinct, report.threads, report.elapsed_s
    );
    if service.machines().len() > 1 {
        println!("tenants: {}", service.machines().join(", "));
    }
    println!(
        "cache: {} hits / {} misses ({:.2}% hit rate)",
        report.cache.hits,
        report.cache.misses,
        report.cache.hit_rate() * 100.0
    );
    println!("lookup latency: p50 {}, p99 {}", fmt_secs(report.p50_s).trim(), fmt_secs(report.p99_s).trim());
    println!("winners:");
    for (label, count) in &report.winners {
        println!("  {label}: {count}");
    }
    if report.cache.hit_rate() < min_hit_rate {
        eprintln!("cache hit rate {:.4} below required {min_hit_rate}", report.cache.hit_rate());
        return 1;
    }
    0
}

fn cmd_advise(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm advise", "online strategy advisor: compiled surfaces, snapshot serving, recalibration")
        .switch("compile", "compile a decision surface and write it to --out")
        .switch("quant", "with --compile: write the compact quantized hetcomm.surface.v3 encoding")
        .switch("query", "answer one strategy query (--q-msgs / --q-size / --q-dest / --q-gpn)")
        .flag("bench-burst", "0", "answer a seeded synthetic burst of N snapshot-served queries")
        .switch("recalibrate", "run the sim-probe recalibration loop (refit -> rebuild a fresh surface)")
        .flag("machine", "lassen", "machine preset, or a comma list to serve a multi-tenant burst fleet")
        .flag("nics", "0", "NIC rails per node to key the surface by (0 = machine preset default)")
        .flag("surface", "", "surface artifact to load (empty = compile in memory from the axis flags)")
        .flag("out", "-", "output path for --compile ('-' = stdout)")
        .flag("msgs", "32,64,128,256,512", "lattice axis: node message counts")
        .flag("sizes", "2^4,2^6,2^8,2^10,2^12,2^14,2^16,2^18,2^20", "lattice axis: message sizes (supports 2^k)")
        .flag("dest", "4,8,16", "lattice axis: destination-node counts")
        .flag("gpn", "4", "lattice axis: GPUs per node")
        .flag("dup", "0.0", "duplicate-data fraction for the lattice")
        .flag("q-msgs", "256", "query: inter-node messages from the node")
        .flag("q-size", "2048", "query: bytes per message")
        .flag("q-dest", "16", "query: destination nodes")
        .flag("q-gpn", "4", "query: GPUs per node")
        .flag("collective", "", "collective mode: rank alltoall/alltoallv/allgather algorithms instead of strategies")
        .flag("q-nodes", "32", "collective query: cluster node count")
        .flag("seed", "42", "burst: base seed (fixed seed => deterministic answers)")
        .flag("threads", "0", "burst: worker threads (0 = all cores)")
        .flag("min-hit-rate", "0.0", "burst: exit nonzero if the cache hit rate falls below this fraction");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };

    if a.get_bool("quant") && !a.get_bool("compile") {
        eprintln!("--quant shapes the --compile output; pass --compile too");
        return 2;
    }

    // Collective mode: --collective reroutes --compile / --query to the
    // locality-aware collective layer (algorithm ranking over a compiled
    // hetcomm.colsurface.v1 lattice).
    if !a.get("collective").is_empty() {
        return advise_collective(&a, argv);
    }

    // A comma list of machines serves a multi-tenant fleet: one surface
    // per machine, all published behind one service, burst-only (the
    // single-target operations below need exactly one machine).
    let machine_list: Vec<String> =
        a.get("machine").split(',').map(|m| m.trim().to_string()).filter(|m| !m.is_empty()).collect();
    if machine_list.len() > 1 {
        if a.get_bool("compile") || a.get_bool("query") || a.get_bool("recalibrate") || !a.get("surface").is_empty() {
            eprintln!("a --machine list only drives --bench-burst; --compile/--query/--recalibrate/--surface target one machine");
            return 2;
        }
        let flags = (a.get_usize("bench-burst"), a.get_u64("seed"), a.get_usize("threads"), a.get_f64("min-hit-rate"));
        let (burst, seed, threads, min_hit_rate) = match flags {
            (Ok(b), Ok(s), Ok(t), Ok(m)) => (b, s, t, m),
            (Err(e), ..) | (_, Err(e), ..) | (_, _, Err(e), _) | (.., Err(e)) => {
                eprintln!("{}", e.0);
                return 2;
            }
        };
        if burst == 0 {
            eprintln!("a --machine list needs --bench-burst N");
            return 2;
        }
        let axes = match advise_axes_from(&a) {
            Ok(axes) => axes,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let (dup, nics) = match (a.get_f64("dup"), a.get_usize("nics")) {
            (Ok(d), Ok(n)) => (d, n),
            (Err(e), _) | (_, Err(e)) => {
                eprintln!("{}", e.0);
                return 2;
            }
        };
        let mut surfaces = Vec::with_capacity(machine_list.len());
        for m in &machine_list {
            match hetcomm::advisor::DecisionSurface::compile_shaped(m, nics, axes.clone(), dup) {
                Ok(s) => surfaces.push(s),
                Err(e) => {
                    eprintln!("cannot compile surface for {m}: {e}");
                    return 2;
                }
            }
        }
        let service = hetcomm::advisor::AdvisorService::new(surfaces);
        return run_advise_burst(&service, burst, seed, threads, min_hit_rate);
    }

    let mut surface = if a.get("surface").is_empty() {
        let axes = match advise_axes_from(&a) {
            Ok(axes) => axes,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let (dup, nics) = match (a.get_f64("dup"), a.get_usize("nics")) {
            (Ok(d), Ok(n)) => (d, n),
            (Err(e), _) | (_, Err(e)) => {
                eprintln!("{}", e.0);
                return 2;
            }
        };
        match hetcomm::advisor::DecisionSurface::compile_shaped(a.get("machine"), nics, axes, dup) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("cannot compile surface: {e}");
                return 2;
            }
        }
    } else {
        match hetcomm::advisor::persist::load(a.get("surface")) {
            Ok(s) => {
                // a loaded artifact defines its own machine; surface an
                // EXPLICIT contradicting --machine instead of silently
                // ignoring it (the flag's default must not trigger this)
                let machine_given = argv.iter().any(|t| t == "--machine" || t.starts_with("--machine="));
                let flag_arch = machines::parse(a.get("machine"), 1).ok();
                if machine_given && flag_arch.as_ref().map(|(m, _)| m.name.as_str()) != Some(s.machine.as_str()) {
                    eprintln!(
                        "note: serving the loaded {} surface (--machine {} ignored)",
                        s.machine,
                        a.get("machine")
                    );
                }
                // same courtesy for the shape key: a loaded artifact fixes it
                let nics_given = argv.iter().any(|t| t == "--nics" || t.starts_with("--nics="));
                if nics_given {
                    eprintln!("note: serving the loaded surface's {} NICs/node (--nics ignored)", s.nics);
                }
                s
            }
            Err(e) => {
                eprintln!("cannot load surface: {e}");
                return 2;
            }
        }
    };

    let mut did_something = false;

    // Recalibrate FIRST so a following --compile persists the refit
    // surface (the compile -> query -> recalibrate -> recompile loop).
    if a.get_bool("recalibrate") {
        did_something = true;
        let (probe_machine, base_params) = match machines::parse(&surface.machine, 2) {
            Ok(mp) => mp,
            Err(e) => {
                eprintln!("surface machine is not in the registry: {e}");
                return 1;
            }
        };
        let mut cal = hetcomm::advisor::Calibrator::new(base_params.clone());
        let probe_sizes: Vec<usize> = (4..=20).map(|e| 1usize << e).collect();
        cal.ingest_sim_probes(&probe_machine, &base_params, &probe_sizes);
        let report = match cal.refit() {
            Ok(r) => r,
            Err(e) => {
                eprintln!("refit failed: {e}");
                return 1;
            }
        };
        // out of place, as the serving path does it: the base surface keeps
        // its bits until the rebuilt one replaces it wholesale
        match report.rebuild(&surface) {
            Ok((next, recompiled)) => {
                println!(
                    "recalibrated {}: {} samples, {} bands refit, {recompiled} cells recompiled into a fresh surface",
                    surface.machine, report.samples, report.bands_refit
                );
                surface = next;
            }
            Err(e) => {
                eprintln!("rebuild failed: {e}");
                return 1;
            }
        }
    }

    if a.get_bool("compile") {
        did_something = true;
        let body = if a.get_bool("quant") {
            match hetcomm::advisor::persist::to_json_quant(&surface) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("cannot encode quantized surface: {e}");
                    return 1;
                }
            }
        } else {
            hetcomm::advisor::persist::to_json(&surface)
        };
        let out = a.get("out");
        if out == "-" {
            print!("{body}");
        } else if let Err(e) = std::fs::write(out, &body) {
            eprintln!("cannot write {out}: {e}");
            return 1;
        } else {
            eprintln!(
                "compiled {}surface for {}: {} lattice cells x {} strategies -> {out}",
                if a.get_bool("quant") { "quantized " } else { "" },
                surface.machine,
                surface.cells.len(),
                surface.strategies.len()
            );
        }
    }

    if a.get_bool("query") {
        did_something = true;
        let parts = (a.get_usize("q-msgs"), a.get_usize("q-size"), a.get_usize("q-dest"), a.get_usize("q-gpn"));
        let pattern = match parts {
            (Ok(n_msgs), Ok(msg_size), Ok(dest_nodes), Ok(gpus_per_node)) => {
                hetcomm::advisor::Pattern { n_msgs, msg_size, dest_nodes, gpus_per_node }
            }
            (Err(e), ..) | (_, Err(e), ..) | (_, _, Err(e), _) | (.., Err(e)) => {
                eprintln!("{}", e.0);
                return 2;
            }
        };
        let ranked = surface.lookup(&pattern);
        let mut t = Table::new(
            format!(
                "Advisor ranking on {}: {} msgs x {} B to {} nodes ({} GPUs/node)",
                surface.machine, pattern.n_msgs, pattern.msg_size, pattern.dest_nodes, pattern.gpus_per_node
            ),
            &["rank", "strategy", "predicted[s]"],
        );
        for (rank, (strategy, secs)) in ranked.ranked.iter().enumerate() {
            t.row(vec![(rank + 1).to_string(), strategy.label().to_string(), fmt_secs(*secs)]);
        }
        t.print();
        let (best, secs) = ranked.best();
        println!("\nfastest: {} ({})", best.label(), fmt_secs(secs));
    }

    let burst = match a.get_usize("bench-burst") {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    if burst > 0 {
        did_something = true;
        let run_flags = (a.get_u64("seed"), a.get_usize("threads"), a.get_f64("min-hit-rate"));
        let (seed, threads, min_hit_rate) = match run_flags {
            (Ok(s), Ok(t), Ok(m)) => (s, t, m),
            (Err(e), ..) | (_, Err(e), _) | (.., Err(e)) => {
                eprintln!("{}", e.0);
                return 2;
            }
        };
        let service = hetcomm::advisor::AdvisorService::new(vec![surface.clone()]);
        let code = run_advise_burst(&service, burst, seed, threads, min_hit_rate);
        if code != 0 {
            return code;
        }
    }

    if !did_something {
        eprintln!("nothing to do: pass --compile, --query, --bench-burst N, or --recalibrate (see --help)");
        return 2;
    }
    0
}

/// The `advise --collective` mode: compile / load a collective decision
/// surface and rank the alltoall/alltoallv/allgather algorithms for a
/// (nodes, size) query.
fn advise_collective(a: &hetcomm::util::cli::Args, argv: &[String]) -> i32 {
    use hetcomm::collective::{persist as col_persist, Collective, CollectiveSurface};
    if a.get_bool("quant") || a.get_bool("recalibrate") {
        eprintln!("--collective mode supports --compile and --query; --quant/--recalibrate serve strategy surfaces");
        return 2;
    }
    match a.get_usize("bench-burst") {
        Ok(0) => {}
        Ok(_) => {
            eprintln!("--collective mode supports --compile and --query; --bench-burst serves strategy surfaces");
            return 2;
        }
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    }
    let Some(collective) = Collective::parse(a.get("collective")) else {
        eprintln!("unknown collective {:?} (alltoall | alltoallv | allgather)", a.get("collective"));
        return 2;
    };

    let surface = if a.get("surface").is_empty() {
        let gpn = match a.get_usize_list("gpn") {
            Ok(v) if v.len() == 1 => v[0],
            Ok(_) => {
                eprintln!("collective surfaces pin one --gpn value");
                return 2;
            }
            Err(e) => {
                eprintln!("{}", e.0);
                return 2;
            }
        };
        let seed = match a.get_u64("seed") {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{}", e.0);
                return 2;
            }
        };
        // the strategy-lattice --sizes default spans 2^4..2^20; the
        // collective lattice has its own default, so only an explicit
        // --sizes overrides it
        let sizes = if argv.iter().any(|t| t == "--sizes" || t.starts_with("--sizes=")) {
            match a.get_usize_list("sizes") {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{}", e.0);
                    return 2;
                }
            }
        } else {
            CollectiveSurface::default_sizes()
        };
        match CollectiveSurface::compile(a.get("machine"), gpn, CollectiveSurface::default_nodes(), sizes, seed) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("cannot compile collective surface: {e}");
                return 2;
            }
        }
    } else {
        match col_persist::load(a.get("surface")) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("cannot load collective surface: {e}");
                return 2;
            }
        }
    };

    let mut did_something = false;
    if a.get_bool("compile") {
        did_something = true;
        let body = col_persist::to_json(&surface);
        let out = a.get("out");
        if out == "-" {
            print!("{body}");
        } else if let Err(e) = std::fs::write(out, &body) {
            eprintln!("cannot write {out}: {e}");
            return 1;
        } else {
            eprintln!(
                "compiled collective surface for {}: {} lattice cells x {} algorithms -> {out}",
                surface.machine,
                surface.cells.len(),
                surface.algorithms.len()
            );
        }
    }

    if a.get_bool("query") {
        did_something = true;
        let (nodes, size) = match (a.get_usize("q-nodes"), a.get_usize("q-size")) {
            (Ok(n), Ok(s)) => (n, s),
            (Err(e), _) | (_, Err(e)) => {
                eprintln!("{}", e.0);
                return 2;
            }
        };
        let Some(ranked) = surface.lookup(collective, nodes, size) else {
            eprintln!("the loaded surface does not cover collective {collective}");
            return 2;
        };
        let mut t = Table::new(
            format!(
                "Collective advisor on {}: {collective}, {nodes} nodes x {size} B blocks ({} GPUs/node)",
                surface.machine, surface.gpus_per_node
            ),
            &["rank", "algorithm", "predicted[s]"],
        );
        for (rank, (alg, secs)) in ranked.ranked.iter().enumerate() {
            t.row(vec![(rank + 1).to_string(), alg.label().to_string(), fmt_secs(*secs)]);
        }
        t.print();
        let (best, secs) = ranked.best();
        println!("\nfastest: {} ({})", best.label(), fmt_secs(secs));
    }

    if !did_something {
        eprintln!("nothing to do in --collective mode: pass --compile and/or --query");
        return 2;
    }
    0
}

/// Parse a `--strategy` spec: a full Table 5 label (`"3-Step (device-aware)"`)
/// or `kind[:transport]` shorthand (`split-md`, `3-step:device-aware`).
fn parse_strategy_spec(spec: &str) -> Result<Strategy, String> {
    if let Some(s) = Strategy::parse_label(spec) {
        return Ok(s);
    }
    let (kind_s, transport_s) = match spec.split_once(':') {
        Some((k, t)) => (k, Some(t)),
        None => (spec, None),
    };
    let kind = StrategyKind::parse(kind_s)
        .ok_or_else(|| format!("unknown strategy kind {kind_s:?} (standard, 3-step, 2-step, split-md, split-dd)"))?;
    let transport = match transport_s {
        None => Transport::Staged,
        Some(t) => Transport::parse(t).ok_or_else(|| format!("unknown transport {t:?} (staged | device-aware)"))?,
    };
    Strategy::new(kind, transport).map_err(|e| e.to_string())
}

fn cmd_replay(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm replay", "trace-driven workload replay with online strategy adaptation")
        .flag("scenario", "amr-drift", "synthetic scenario (amr-drift | sparsify | rebalance | halo-burst | stationary)")
        .flag("trace", "", "load a hetcomm.trace.v1 artifact instead of synthesizing")
        .switch("record", "record a distributed-SpMV proxy run through the persistent engine instead of synthesizing")
        .flag("matrix", "audikw_1", "record: SuiteSparse proxy matrix")
        .flag("scale", "256", "record: proxy row divisor")
        .flag("gpus", "8", "record: partition count")
        .flag("nodes", "2", "record: cluster nodes")
        .flag("iters", "4", "record: iterations to record")
        .flag(
            "machine",
            "lassen",
            "scenario/record: machine preset (lassen | summit | frontier-like | frontier-4nic | delta-like)",
        )
        .flag("epochs", "5", "scenario: epoch (plateau) count")
        .flag("repeat", "0", "scenario: iterations per epoch (0 = scenario default)")
        .flag("seed", "42", "scenario: message-order shuffle seed (recorded in the trace)")
        .flag("out", "", "write the trace as a hetcomm.trace.v1 artifact at this path")
        .switch("replay", "replay the trace (implied by --adaptive / --strategy; adaptive is the default policy)")
        .switch("adaptive", "adaptive policy: re-advise whenever drift exceeds --threshold")
        .flag("strategy", "", "static policy: kind[:transport], e.g. split-md or 3-step:device-aware")
        .flag("surface", "", "adaptive: advise from this compiled surface artifact (default: exact Table 6 ranking)")
        .flag("threshold", "0.25", "adaptive: drift threshold in |log2| units")
        .flag("faults", "", "inject a hetcomm.faults.v1 schedule: degrade rails mid-replay and report resilience")
        .switch("sim", "also run each epoch's chosen schedule through the discrete-event simulator")
        .flag("format", "table", "report format: table | json")
        .flag("report", "-", "report output path ('-' = stdout)")
        .flag("min-win", "", "exit nonzero unless the win vs the best static strategy is >= this fraction");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };

    if !a.get("trace").is_empty() && a.get_bool("record") {
        eprintln!("--trace and --record are mutually exclusive (load a trace or record one, not both)");
        return 2;
    }

    // 1. Acquire the trace: load, record, or synthesize.
    let trace = if !a.get("trace").is_empty() {
        match hetcomm::trace::persist::load(a.get("trace")) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("cannot load trace: {e}");
                return 2;
            }
        }
    } else {
        let seed = match a.get_u64("seed") {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{}", e.0);
                return 2;
            }
        };
        if a.get_bool("record") {
            let parts = (a.get_usize("scale"), a.get_usize("gpus"), a.get_usize("nodes"), a.get_usize("iters"));
            let (scale, gpus, nodes, iters) = match parts {
                (Ok(s), Ok(g), Ok(n), Ok(i)) => (s, g, n, i),
                (Err(e), ..) | (_, Err(e), ..) | (_, _, Err(e), _) | (.., Err(e)) => {
                    eprintln!("{}", e.0);
                    return 2;
                }
            };
            let machine = match machines::parse(a.get("machine"), nodes) {
                Ok((m, _)) => m,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            match hetcomm::trace::record::record_spmv(a.get("matrix"), scale, gpus, &machine, iters, seed) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("recording failed: {e}");
                    return 1;
                }
            }
        } else {
            let Some(scenario) = hetcomm::trace::TraceScenario::parse(a.get("scenario")) else {
                eprintln!(
                    "unknown scenario {:?}; known: {:?}",
                    a.get("scenario"),
                    hetcomm::trace::TraceScenario::ALL.map(|s| s.label())
                );
                return 2;
            };
            let (epochs, repeat) = match (a.get_usize("epochs"), a.get_usize("repeat")) {
                (Ok(e), Ok(r)) => (e, r),
                (Err(e), _) | (_, Err(e)) => {
                    eprintln!("{}", e.0);
                    return 2;
                }
            };
            match hetcomm::trace::synthesize(scenario, a.get("machine"), epochs, repeat, seed) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("cannot synthesize {scenario}: {e}");
                    return 2;
                }
            }
        }
    };

    let faults = if a.get("faults").is_empty() {
        None
    } else {
        match hetcomm::fault::persist::load(a.get("faults")) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("cannot load fault spec: {e}");
                return 2;
            }
        }
    };

    // 2. Persist the trace when asked — with the fault schedule embedded in
    //    its epochs, so the artifact is self-describing (replaying it later
    //    re-fires the events with no --faults flag).
    if !a.get("out").is_empty() {
        let to_save = match &faults {
            Some(spec) => match spec.attach(&trace) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("cannot embed fault schedule in the trace: {e}");
                    return 2;
                }
            },
            None => trace.clone(),
        };
        if let Err(e) = hetcomm::trace::persist::save(&to_save, a.get("out")) {
            eprintln!("{e}");
            return 1;
        }
        eprintln!(
            "wrote trace {}: {} epochs, {} iterations -> {}",
            trace.scenario,
            trace.epochs.len(),
            trace.iterations(),
            a.get("out")
        );
    }

    // 3. Replay unless this was a record/synthesize-only invocation
    //    (--min-win asserts on and --surface configures the replay, so
    //    either forces it too).
    let static_spec = a.get("strategy");
    let wants_replay = a.get_bool("replay")
        || a.get_bool("adaptive")
        || !static_spec.is_empty()
        || !a.get("min-win").is_empty()
        || !a.get("surface").is_empty()
        || a.get("out").is_empty();
    if !wants_replay {
        return 0;
    }
    if a.get_bool("adaptive") && !static_spec.is_empty() {
        eprintln!("--adaptive and --strategy are mutually exclusive policies");
        return 2;
    }
    let surface = if a.get("surface").is_empty() {
        None
    } else {
        match hetcomm::advisor::persist::load(a.get("surface")) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("cannot load surface: {e}");
                return 2;
            }
        }
    };
    let static_strategy = if static_spec.is_empty() {
        None
    } else {
        if surface.is_some() {
            eprintln!("--surface only applies to the adaptive policy");
            return 2;
        }
        match parse_strategy_spec(static_spec) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    };
    let mode = match &static_strategy {
        Some(s) => hetcomm::trace::ReplayMode::Static(*s),
        None => hetcomm::trace::ReplayMode::Adaptive { surface: surface.as_ref() },
    };
    let threshold = match a.get_f64("threshold") {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let config = hetcomm::trace::replay::ReplayConfig { drift_threshold: threshold, sim: a.get_bool("sim") };
    let report = match hetcomm::trace::replay_with_faults(&trace, &mode, &config, faults.as_ref()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("replay failed: {e}");
            return 1;
        }
    };

    let body = match a.get("format") {
        "json" => hetcomm::trace::replay::report_to_json(&report),
        "table" => hetcomm::trace::replay::render_report(&report),
        other => {
            eprintln!("unknown format {other:?} (table | json)");
            return 2;
        }
    };
    let report_path = a.get("report");
    if report_path == "-" {
        print!("{body}");
    } else if let Err(e) = std::fs::write(report_path, &body) {
        eprintln!("cannot write {report_path}: {e}");
        return 1;
    }
    eprintln!(
        "replayed {} ({}): {} iterations, {} switches, win vs best static {:+.2}%",
        report.scenario,
        report.mode,
        report.iterations,
        report.switches.len(),
        report.win_vs_best_static * 100.0
    );
    if let Some(res) = &report.resilience {
        let recovery = match res.recovery_epochs {
            Some(e) => format!("first post-fault switch after {e} epoch(s)"),
            None => "no post-fault switch".to_string(),
        };
        eprintln!("resilience: most robust static {}, {recovery}", res.most_robust.label());
    }

    if !a.get("min-win").is_empty() {
        let min_win = match a.get_f64("min-win") {
            Ok(m) => m,
            Err(e) => {
                eprintln!("{}", e.0);
                return 2;
            }
        };
        if report.win_vs_best_static < min_win {
            eprintln!("win {:.4} below required {min_win}", report.win_vs_best_static);
            return 1;
        }
    }
    0
}

fn cmd_spmv(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm spmv", "distributed SpMV communication benchmark")
        .flag("matrix", "audikw_1", "SuiteSparse matrix name (proxy)")
        .flag("scale", "64", "row divisor for the proxy")
        .flag("gpus", "8", "partition count")
        .flag("nodes", "2", "cluster nodes")
        .flag("iters", "3", "repetitions")
        .flag("machine", "lassen", "machine preset (lassen | summit | frontier-like | frontier-4nic | delta-like)")
        .switch("pjrt", "run local compute through the PJRT artifact");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let Some(info) = suite::info(a.get("matrix")) else {
        eprintln!("unknown matrix {:?}; known: {:?}", a.get("matrix"), suite::MATRICES.map(|m| m.name));
        return 2;
    };
    let mat = suite::proxy(info, a.get_usize("scale").unwrap());
    let (machine, _params) = match machines::parse(a.get("machine"), a.get_usize("nodes").unwrap()) {
        Ok(mp) => mp,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let gpus = a.get_usize("gpus").unwrap();
    println!("matrix {} proxy: {} rows, {} nnz over {gpus} GPUs", info.name, mat.nrows, mat.nnz());

    let mut v = vec![0f32; mat.nrows];
    for (i, x) in v.iter_mut().enumerate() {
        *x = ((i % 17) as f32 - 8.0) / 8.0;
    }
    let cfg = SpmvConfig { use_pjrt: a.get_bool("pjrt"), ..Default::default() };
    let mut t = Table::new(
        format!("SpMV comm: {} ({} GPUs)", info.name, gpus),
        &["strategy", "sim[s]", "wall-ex[s]", "msgs", "verified"],
    );
    for s in Strategy::all().into_iter().filter(|s| s.transport == Transport::Staged || s.kind != StrategyKind::Standard) {
        // Data-plane execution is transport-agnostic; run each kind once
        // (staged) and report the simulated time for the exact transport.
        if s.transport == Transport::DeviceAware {
            continue;
        }
        match DistSpmv::new(&mat, gpus, &machine, s, cfg.clone()) {
            Ok(d) => match d.run(&v, a.get_usize("iters").unwrap()) {
                Ok(rep) => t.row(vec![
                    s.label().to_string(),
                    fmt_secs(rep.sim_exchange_per_iter),
                    fmt_secs(rep.wall_exchange),
                    rep.msgs_per_iter.to_string(),
                    format!("{:?}", rep.verified),
                ]),
                Err(e) => {
                    let msg = format!("run error: {e}");
                    t.row(vec![s.label().to_string(), msg, String::new(), String::new(), String::new()])
                }
            },
            Err(e) => {
                let msg = format!("setup error: {e}");
                t.row(vec![s.label().to_string(), msg, String::new(), String::new(), String::new()])
            }
        }
    }
    t.print();
    0
}

fn cmd_perf(argv: &[String]) -> i32 {
    use hetcomm::bench::perf;
    let cli = Cli::new("hetcomm perf", "hot-path self-benchmarks with a committed baseline trajectory")
        .switch("quick", "run the CI-sized workload instead of the full one")
        .flag("suite", "sweep", "benchmark family: sweep (simulator hot paths) | advise (serving engine)")
        .flag("seed", "42", "base seed (fixed seed => byte-deterministic projection)")
        .flag("threads", "0", "worker threads (0 = all cores; answers never depend on this)")
        .flag("out", "-", "write the hetcomm.bench.v1 report to this path ('-' = stdout)")
        .switch("no-timing", "emit the deterministic projection (wall-clock fields as null)")
        .flag("baseline", "", "compare against a committed hetcomm.bench.v1 artifact (BENCH_sweep.json / BENCH_advise.json)")
        .flag("min-speedup", "", "fail unless the suite's fast/reference throughput ratio is >= this (default: 2.0 for sweep, 0.0 for advise)")
        .flag("max-regression", "0.5", "fail if throughput falls below (1 - this) x baseline")
        .switch("selfcheck", "run the workload twice and require a byte-identical deterministic projection");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let Some(suite) = perf::Suite::parse(a.get("suite")) else {
        eprintln!("unknown suite {:?} (sweep | advise)", a.get("suite"));
        return 2;
    };
    let parsed = (a.get_u64("seed"), a.get_usize("threads"), a.get_f64("max-regression"));
    let (seed, threads, max_regression) = match parsed {
        (Ok(s), Ok(t), Ok(r)) => (s, t, r),
        (Err(e), ..) | (_, Err(e), _) | (.., Err(e)) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    // The sweep suite's 2x compiled-vs-reference margin is a product claim;
    // the advise suite's wall-clock ratio is noisy at microsecond scale, so
    // its default gate is the checksums, not a throughput floor.
    let min_speedup = if a.get("min-speedup").is_empty() {
        match suite {
            perf::Suite::Sweep => 2.0,
            perf::Suite::Advise => 0.0,
        }
    } else {
        match a.get_f64("min-speedup") {
            Ok(m) => m,
            Err(e) => {
                eprintln!("{}", e.0);
                return 2;
            }
        }
    };
    let config = perf::PerfConfig { quick: a.get_bool("quick"), seed, threads, suite };
    let report = match perf::run_perf(&config) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("perf harness failed: {e}");
            return 1;
        }
    };
    let timing = !a.get_bool("no-timing");
    let body = perf::report_to_json(&report, timing);

    // the emitter must always produce a schema-valid artifact
    let doc = match hetcomm::util::json::Json::parse(&body) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("internal error: emitted report is not valid JSON: {e}");
            return 1;
        }
    };
    if let Err(e) = perf::validate_artifact(&doc) {
        eprintln!("internal error: emitted report fails schema validation: {e}");
        return 1;
    }

    if a.get_bool("selfcheck") {
        match perf::run_perf(&config) {
            Ok(second) => {
                let (p1, p2) = (perf::report_to_json(&report, false), perf::report_to_json(&second, false));
                if p1 != p2 {
                    eprintln!("selfcheck failed: two runs produced different deterministic projections");
                    return 1;
                }
                eprintln!("selfcheck: deterministic projection byte-identical across two runs");
            }
            Err(e) => {
                eprintln!("selfcheck rerun failed: {e}");
                return 1;
            }
        }
    }

    let out_path = a.get("out");
    if out_path == "-" {
        print!("{body}");
    } else if let Err(e) = std::fs::write(out_path, &body) {
        eprintln!("cannot write {out_path}: {e}");
        return 1;
    } else {
        eprintln!("wrote {} report to {out_path}", perf::SCHEMA);
    }

    for row in &report.results {
        eprintln!(
            "{:>16}: {:>10.1} items/s ({} items, p50 {}, p99 {})",
            row.name,
            row.items_per_sec,
            row.items,
            fmt_secs(row.p50_s).trim(),
            fmt_secs(row.p99_s).trim()
        );
    }
    let speedup_kind = match suite {
        perf::Suite::Sweep => "compiled-vs-reference sweep",
        perf::Suite::Advise => "batched-vs-per-query advise",
    };
    eprintln!("{speedup_kind} speedup: {:.2}x (required {min_speedup:.2}x)", report.speedup_vs_reference);
    if report.speedup_vs_reference < min_speedup {
        eprintln!("speedup below the required margin");
        return 1;
    }

    let baseline_path = a.get("baseline");
    if !baseline_path.is_empty() {
        let text = match std::fs::read_to_string(baseline_path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("cannot read baseline {baseline_path}: {e}");
                return 1;
            }
        };
        match perf::compare_baseline(&report, &text, max_regression) {
            Ok(notes) => {
                for note in notes {
                    eprintln!("baseline: {note}");
                }
            }
            Err(e) => {
                eprintln!("baseline comparison failed: {e}");
                return 1;
            }
        }
    }
    0
}

fn cmd_validate(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm validate", "model vs simulated SpMV communication (Figure 4.2)")
        .flag("scale", "64", "proxy scale")
        .flag("gpus", "16", "partition count")
        .flag("nodes", "4", "cluster nodes");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let info = suite::info("audikw_1").unwrap();
    let mat = suite::proxy(info, a.get_usize("scale").unwrap());
    let machine = machines::lassen(a.get_usize("nodes").unwrap());
    let params = lassen_params();
    let gpus = a.get_usize("gpus").unwrap();
    let pm = PartitionedMatrix::build(&mat, gpus);
    let pattern = pm.comm_pattern(&machine, 8);
    let dup = pattern.duplicate_fraction(&machine);
    let sm = StrategyModel::new(&machine, &params);

    let mut t = Table::new(
        format!("Model validation: audikw_1 proxy on {gpus} GPUs (dup {:.1}%)", dup * 100.0),
        &["strategy", "model[s]", "simulated[s]", "ratio"],
    );
    for s in Strategy::all() {
        let ppn = s.sim_ppn(&machine);
        let inputs = pattern.model_inputs(&machine, ppn, dup);
        let model = sm.time(s, &inputs);
        let sched = hetcomm::comm::build_schedule(s, &machine, &pattern);
        let simd = hetcomm::sim::run(&machine, &params, &sched, ppn).total;
        t.row(vec![s.label().to_string(), fmt_secs(model), fmt_secs(simd), format!("{:.2}", model / simd)]);
    }
    t.print();
    0
}

fn cmd_study(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm study", "Section 6 outlook: best strategy on current and future machines")
        .flag("msgs", "256", "inter-node messages per node")
        .flag("dest", "16", "destination nodes")
        .flag("machine", "all", "lassen | frontier | frontier-4nic | delta | all")
        .flag("bw-scale", "0", "interconnect bandwidth multiplier (0 = per-machine default)")
        .flag("sizes", "2^8,2^10,2^12,2^14,2^16,2^18", "message sizes");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let base = lassen_params();
    let chosen = a.get("machine");
    let bw_override = a.get_f64("bw-scale").unwrap();
    let mut configs: Vec<(&str, hetcomm::Machine, hetcomm::MachineParams)> = Vec::new();
    if chosen == "all" || chosen == "lassen" {
        configs.push(("lassen", machines::lassen(32), base.clone()));
    }
    if chosen == "all" || chosen == "frontier" {
        let bw = if bw_override > 0.0 { bw_override } else { 4.0 };
        configs.push(("frontier-like", machines::frontier_like(32), base.scaled(0.8, bw)));
    }
    if chosen == "all" || chosen == "frontier-4nic" {
        // resource-graph view: 4 explicit rails at the (possibly overridden)
        // per-rail bandwidth instead of one aggregate-scaled rail
        let bw = if bw_override > 0.0 { bw_override } else { 1.0 };
        configs.push(("frontier-4nic", machines::frontier_4nic(32), base.scaled(0.8, bw)));
    }
    if chosen == "all" || chosen == "delta" {
        let bw = if bw_override > 0.0 { bw_override } else { 2.0 };
        configs.push(("delta-like", machines::delta_like(32), base.scaled(1.0, bw)));
    }
    if configs.is_empty() {
        eprintln!("unknown machine {chosen:?}");
        return 2;
    }
    let mut t = Table::new(
        format!("Section 6 study — {} msgs to {} nodes", a.get("msgs"), a.get("dest")),
        &["machine", "cores/node", "size[B]", "best strategy", "modeled[s]"],
    );
    for (name, machine, params) in &configs {
        let sm = StrategyModel::new(machine, params);
        for size in a.get_usize_list("sizes").unwrap() {
            let sc = Scenario {
                n_msgs: a.get_usize("msgs").unwrap(),
                msg_size: size,
                n_dest: a.get_usize("dest").unwrap(),
                dup_frac: 0.0,
            };
            let inputs = sc.inputs(machine, machine.cores_per_node());
            let (best, secs) = sm.best(&inputs);
            t.row(vec![
                name.to_string(),
                machine.cores_per_node().to_string(),
                size.to_string(),
                best.label().to_string(),
                fmt_secs(secs),
            ]);
        }
    }
    t.print();
    0
}

fn cmd_e2e(argv: &[String]) -> i32 {
    let cli = Cli::new("hetcomm e2e", "end-to-end power iteration through PJRT")
        .flag("side", "8", "stencil cube side (rows = side^3)")
        .flag("gpus", "8", "partition count")
        .flag("nodes", "2", "cluster nodes")
        .flag("iters", "20", "power iterations")
        .flag("artifacts", "artifacts", "artifact directory")
        .switch("no-pjrt", "use the in-Rust kernel instead of PJRT");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{}", e.0);
            return 2;
        }
    };
    let side = a.get_usize("side").unwrap();
    // 2x depth keeps per-part slabs >= 2 layers thick so the offd block
    // fits the artifact's static ELL width.
    let mat = hetcomm::sparse::gen::stencil_27pt(side, side, 2 * side);
    let machine = machines::lassen(a.get_usize("nodes").unwrap());
    let cfg = SpmvConfig {
        use_pjrt: !a.get_bool("no-pjrt"),
        artifacts_dir: a.get("artifacts").into(),
        ..Default::default()
    };
    let strategy = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();
    let d = match DistSpmv::new(&mat, a.get_usize("gpus").unwrap(), &machine, strategy, cfg) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("setup failed: {e:#}");
            return 1;
        }
    };
    let v0 = vec![1f32; mat.nrows];
    match d.power_iterate(&v0, a.get_usize("iters").unwrap()) {
        Ok((_, lambda, t_ex, t_cp)) => {
            println!("power iteration converged: lambda={lambda:.4} exchange={t_ex:.4}s compute={t_cp:.4}s");
            println!("sim exchange/iter: {}", fmt_secs(d.sim_report.total));
            0
        }
        Err(e) => {
            eprintln!("e2e failed: {e:#}");
            1
        }
    }
}
