//! Measured modeling parameters (Section 3, Tables 2–4).
//!
//! Every data-flow path between two CPUs or two GPUs is characterised by a
//! postal-model pair (α latency, β per-byte cost) keyed by *locality*
//! (on-socket / on-node / off-node) and *MPI messaging protocol*
//! (short / eager / rendezvous). CPU↔GPU copies (`cudaMemcpyAsync`) are
//! characterised separately (Table 3), and the NIC injection-bandwidth limit
//! `R_N` (Table 4) feeds the max-rate model.
//!
//! The constants below are the paper's measured Lassen values; alternative
//! machines can load their own tables from config files
//! ([`MachineParams::from_config`]) or be derived by scaling
//! ([`MachineParams::scaled`]).

pub mod fit;

use crate::topology::Locality;
use crate::util::config::{Config, ConfigError};

/// MPI point-to-point messaging protocol (Section 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Protocol {
    /// Fits in the envelope; sent immediately.
    Short,
    /// Receiver buffer assumed pre-allocated.
    Eager,
    /// Receiver must allocate before transfer (handshake).
    Rendezvous,
}

impl std::fmt::Display for Protocol {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Protocol::Short => write!(f, "short"),
            Protocol::Eager => write!(f, "eager"),
            Protocol::Rendezvous => write!(f, "rend"),
        }
    }
}

/// A postal-model (α, β) pair: `T(s) = α + β s`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AlphaBeta {
    /// Latency [s].
    pub alpha: f64,
    /// Per-byte transfer cost [s/B].
    pub beta: f64,
}

impl AlphaBeta {
    pub const fn new(alpha: f64, beta: f64) -> Self {
        AlphaBeta { alpha, beta }
    }

    /// Postal-model time for an `s`-byte message (Eq. 2.1).
    pub fn time(&self, s: usize) -> f64 {
        self.alpha + self.beta * s as f64
    }
}

/// Which endpoint memory a message moves between (selects the CPU vs GPU
/// block of Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Endpoint {
    Cpu,
    Gpu,
}

/// Direction of a host↔device copy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CopyDir {
    H2D,
    D2H,
}

/// Complete measured parameter set for one machine.
#[derive(Clone, Debug, PartialEq)]
pub struct MachineParams {
    /// CPU↔CPU (α, β) per protocol × locality.
    pub cpu: [[AlphaBeta; 3]; 3],
    /// GPU↔GPU device-aware (α, β): eager & rendezvous only (short is not
    /// used for device-aware transfers on Lassen).
    pub gpu: [[AlphaBeta; 3]; 2],
    /// cudaMemcpyAsync (α, β): rows = #processes class (0 → 1 proc,
    /// 1 → 4 procs), cols = direction (H2D, D2H).
    pub memcpy: [[AlphaBeta; 2]; 2],
    /// Inverse NIC injection rate `1/R_N` [s/B] for staged (CPU) traffic.
    pub inv_rn: f64,
    /// Per-NIC injection bands, one per rail of the node shape: `alpha` is
    /// the per-transfer injection setup charged to the rail, `beta` the
    /// inverse injection rate [s/B]. Empty (the default) means homogeneous
    /// rails at `(0, inv_rn)` — exactly the pre-shape-layer NIC; rails
    /// beyond the table's length also fall back to `(0, inv_rn)`.
    pub nic_bands: Vec<AlphaBeta>,
    /// Byte thresholds for protocol switching: messages `< short_max` are
    /// short, `< eager_max` eager, otherwise rendezvous.
    pub short_max: usize,
    pub eager_max: usize,
    /// GPU (device-aware) eager→rendezvous switch point.
    pub gpu_eager_max: usize,
}

const IDX_SHORT: usize = 0;
const IDX_EAGER: usize = 1;
const IDX_REND: usize = 2;

fn loc_idx(l: Locality) -> usize {
    match l {
        Locality::OnSocket => 0,
        Locality::OnNode => 1,
        Locality::OffNode => 2,
    }
}

/// The paper's measured Lassen parameters (Tables 2–4, Spectrum MPI).
pub fn lassen_params() -> MachineParams {
    MachineParams {
        cpu: [
            // short:        on-socket                on-node                  off-node
            [
                AlphaBeta::new(3.67e-7, 1.32e-10),
                AlphaBeta::new(9.25e-7, 1.19e-9),
                AlphaBeta::new(1.89e-6, 6.88e-10),
            ],
            // eager
            [
                AlphaBeta::new(4.61e-7, 7.12e-11),
                AlphaBeta::new(1.17e-6, 2.18e-10),
                AlphaBeta::new(2.44e-6, 3.79e-10),
            ],
            // rendezvous
            [
                AlphaBeta::new(3.15e-6, 3.40e-11),
                AlphaBeta::new(6.77e-6, 1.49e-10),
                AlphaBeta::new(7.76e-6, 7.97e-11),
            ],
        ],
        gpu: [
            // eager
            [
                AlphaBeta::new(1.87e-6, 5.79e-11),
                AlphaBeta::new(2.02e-5, 2.15e-10),
                AlphaBeta::new(8.95e-6, 1.72e-10),
            ],
            // rendezvous
            [
                AlphaBeta::new(1.82e-5, 1.46e-11),
                AlphaBeta::new(1.93e-5, 2.39e-11),
                AlphaBeta::new(1.10e-5, 1.72e-10),
            ],
        ],
        memcpy: [
            // 1 proc:      H2D                       D2H
            [AlphaBeta::new(1.30e-5, 1.85e-11), AlphaBeta::new(1.27e-5, 1.96e-11)],
            // 4 procs (duplicate device pointers)
            [AlphaBeta::new(1.52e-5, 5.52e-10), AlphaBeta::new(1.47e-5, 1.50e-10)],
        ],
        inv_rn: 4.19e-11,
        nic_bands: Vec::new(),
        // Spectrum MPI on Lassen: envelope-sized messages up to 512 B,
        // eager up to the 8 KiB rendezvous switch the paper (and [16]) use
        // as the Split message cap.
        short_max: 512,
        eager_max: 8192,
        gpu_eager_max: 8192,
    }
}

impl MachineParams {
    /// Protocol selected for an `s`-byte CPU message. The eager bound is
    /// inclusive: Spectrum MPI sends messages up to and including the eager
    /// limit eagerly, which is why the Split message cap *equals* the
    /// rendezvous switch point (8 KiB chunks still travel eagerly) [16].
    pub fn cpu_protocol(&self, s: usize) -> Protocol {
        if s < self.short_max {
            Protocol::Short
        } else if s <= self.eager_max {
            Protocol::Eager
        } else {
            Protocol::Rendezvous
        }
    }

    /// Protocol selected for an `s`-byte device-aware GPU message
    /// (eager bound inclusive, as for CPUs).
    pub fn gpu_protocol(&self, s: usize) -> Protocol {
        if s <= self.gpu_eager_max {
            Protocol::Eager
        } else {
            Protocol::Rendezvous
        }
    }

    /// (α, β) for a CPU↔CPU message of explicit protocol and locality.
    pub fn cpu_ab(&self, p: Protocol, l: Locality) -> AlphaBeta {
        let pi = match p {
            Protocol::Short => IDX_SHORT,
            Protocol::Eager => IDX_EAGER,
            Protocol::Rendezvous => IDX_REND,
        };
        self.cpu[pi][loc_idx(l)]
    }

    /// (α, β) for a GPU↔GPU device-aware message of explicit protocol.
    /// `Short` is promoted to `Eager` (short is unused device-aware).
    pub fn gpu_ab(&self, p: Protocol, l: Locality) -> AlphaBeta {
        let pi = match p {
            Protocol::Short | Protocol::Eager => 0,
            Protocol::Rendezvous => 1,
        };
        self.gpu[pi][loc_idx(l)]
    }

    /// (α, β) for an `s`-byte message between endpoints of kind `ep` at
    /// locality `l`, with protocol chosen by size.
    pub fn ab_for(&self, ep: Endpoint, l: Locality, s: usize) -> AlphaBeta {
        match ep {
            Endpoint::Cpu => self.cpu_ab(self.cpu_protocol(s), l),
            Endpoint::Gpu => self.gpu_ab(self.gpu_protocol(s), l),
        }
    }

    /// Postal-model time for one message (Eq. 2.1 with Table 2 parameters).
    pub fn msg_time(&self, ep: Endpoint, l: Locality, s: usize) -> f64 {
        self.ab_for(ep, l, s).time(s)
    }

    /// (α, β) for a host↔device copy using `nprocs` simultaneous processes
    /// (1 or 4 measured; 2–3 use the 4-proc class, >4 unsupported per the
    /// paper's observation that more than four brings no benefit).
    pub fn memcpy_ab(&self, dir: CopyDir, nprocs: usize) -> AlphaBeta {
        assert!(nprocs >= 1 && nprocs <= 4, "memcpy procs {nprocs} outside measured range 1..=4");
        let row = if nprocs == 1 { 0 } else { 1 };
        let col = match dir {
            CopyDir::H2D => 0,
            CopyDir::D2H => 1,
        };
        self.memcpy[row][col]
    }

    /// Time to copy `s` bytes between host and device with `nprocs`
    /// processes; when `nprocs > 1`, each process copies `s / nprocs` bytes
    /// concurrently (the measured 4-proc β already reflects contention).
    pub fn memcpy_time(&self, dir: CopyDir, s: usize, nprocs: usize) -> f64 {
        let ab = self.memcpy_ab(dir, nprocs);
        ab.time(s.div_ceil(nprocs.max(1)))
    }

    /// NIC injection rate `R_N` [B/s].
    pub fn rn(&self) -> f64 {
        1.0 / self.inv_rn
    }

    /// Injection band of one NIC rail: the explicit per-rail entry when the
    /// table has one, otherwise the homogeneous `(0, inv_rn)` default.
    pub fn nic_band(&self, rail: usize) -> AlphaBeta {
        self.nic_bands.get(rail).copied().unwrap_or(AlphaBeta::new(0.0, self.inv_rn))
    }

    /// Occupancy one transfer places on a NIC rail: `α + bytes·β` of the
    /// rail's band. With the default homogeneous bands this is bit-identical
    /// to the historical `bytes / R_N` (`0.0 + x == x`).
    pub fn nic_busy(&self, rail: usize, bytes: usize) -> f64 {
        let band = self.nic_band(rail);
        band.alpha + bytes as f64 * band.beta
    }

    /// Uniformly scale all latencies (α) and bandwidths (1/β, R_N) — used to
    /// derive forward-looking machines (Section 6: "higher bandwidth
    /// interconnects") from the Lassen baseline.
    pub fn scaled(&self, alpha_scale: f64, bw_scale: f64) -> MachineParams {
        let s = |ab: AlphaBeta| AlphaBeta::new(ab.alpha * alpha_scale, ab.beta / bw_scale);
        let mut out = self.clone();
        for p in 0..3 {
            for l in 0..3 {
                out.cpu[p][l] = s(self.cpu[p][l]);
            }
        }
        for p in 0..2 {
            for l in 0..3 {
                out.gpu[p][l] = s(self.gpu[p][l]);
            }
        }
        for r in 0..2 {
            for c in 0..2 {
                out.memcpy[r][c] = s(self.memcpy[r][c]);
            }
        }
        out.inv_rn = self.inv_rn / bw_scale;
        out.nic_bands = self.nic_bands.iter().map(|&b| s(b)).collect();
        out
    }

    /// Memoize the protocol-band selection into per-(endpoint, locality)
    /// piecewise tables for the simulator hot path. The compiled form
    /// answers [`CompiledParams::msg_time`] with one bounded linear scan
    /// over at most two size cuts instead of re-branching through
    /// [`MachineParams::cpu_protocol`] / [`MachineParams::gpu_protocol`] and
    /// the row-index matches on every call; results are bit-for-bit
    /// identical to [`MachineParams::msg_time`].
    pub fn compile(&self) -> CompiledParams {
        let cpu_table = |l: Locality| MsgTimeTable {
            // cpu_protocol: s < short_max -> short; s <= eager_max -> eager
            // (inclusive bound); else rendezvous.
            cuts: [self.short_max, self.eager_max.saturating_add(1)],
            n_cuts: 2,
            ab: [
                self.cpu_ab(Protocol::Short, l),
                self.cpu_ab(Protocol::Eager, l),
                self.cpu_ab(Protocol::Rendezvous, l),
            ],
        };
        let gpu_table = |l: Locality| MsgTimeTable {
            // gpu_protocol: s <= gpu_eager_max -> eager (inclusive); else rend.
            cuts: [self.gpu_eager_max.saturating_add(1), usize::MAX],
            n_cuts: 1,
            ab: [
                self.gpu_ab(Protocol::Eager, l),
                self.gpu_ab(Protocol::Rendezvous, l),
                self.gpu_ab(Protocol::Rendezvous, l),
            ],
        };
        let locs = [Locality::OnSocket, Locality::OnNode, Locality::OffNode];
        CompiledParams {
            tables: [locs.map(cpu_table), locs.map(gpu_table)],
            memcpy: self.memcpy,
            inv_rn: self.inv_rn,
            nic_bands: self.nic_bands.clone(),
        }
    }

    /// Load a parameter table from a config file with `[cpu.short]`,
    /// `[cpu.eager]`, `[cpu.rend]`, `[gpu.eager]`, `[gpu.rend]`,
    /// `[memcpy.p1]`, `[memcpy.p4]` and `[network]` sections. Missing
    /// sections fall back to Lassen values.
    pub fn from_config(cfg: &Config) -> Result<MachineParams, ConfigError> {
        let mut p = lassen_params();
        let read_loc3 = |sec: &crate::util::config::Section, dst: &mut [AlphaBeta; 3]| -> Result<(), ConfigError> {
            for (i, loc) in ["on_socket", "on_node", "off_node"].iter().enumerate() {
                dst[i] = AlphaBeta::new(
                    sec.f64_or(&format!("alpha_{loc}"), dst[i].alpha)?,
                    sec.f64_or(&format!("beta_{loc}"), dst[i].beta)?,
                );
            }
            Ok(())
        };
        for (name, pi) in [("cpu.short", 0usize), ("cpu.eager", 1), ("cpu.rend", 2)] {
            if let Some(sec) = cfg.section_opt(name) {
                let mut row = p.cpu[pi];
                read_loc3(sec, &mut row)?;
                p.cpu[pi] = row;
            }
        }
        for (name, pi) in [("gpu.eager", 0usize), ("gpu.rend", 1)] {
            if let Some(sec) = cfg.section_opt(name) {
                let mut row = p.gpu[pi];
                read_loc3(sec, &mut row)?;
                p.gpu[pi] = row;
            }
        }
        for (name, ri) in [("memcpy.p1", 0usize), ("memcpy.p4", 1)] {
            if let Some(sec) = cfg.section_opt(name) {
                p.memcpy[ri][0] = AlphaBeta::new(
                    sec.f64_or("alpha_h2d", p.memcpy[ri][0].alpha)?,
                    sec.f64_or("beta_h2d", p.memcpy[ri][0].beta)?,
                );
                p.memcpy[ri][1] = AlphaBeta::new(
                    sec.f64_or("alpha_d2h", p.memcpy[ri][1].alpha)?,
                    sec.f64_or("beta_d2h", p.memcpy[ri][1].beta)?,
                );
            }
        }
        if let Some(sec) = cfg.section_opt("network") {
            p.inv_rn = sec.f64_or("inv_rn", p.inv_rn)?;
            p.short_max = sec.usize_or("short_max", p.short_max)?;
            p.eager_max = sec.usize_or("eager_max", p.eager_max)?;
            p.gpu_eager_max = sec.usize_or("gpu_eager_max", p.gpu_eager_max)?;
            // optional explicit per-rail bands: `nic_rails` homogeneous
            // rails with `nic_alpha` injection setup each
            let rails = sec.usize_or("nic_rails", 0)?;
            let nic_alpha = sec.f64_or("nic_alpha", 0.0)?;
            if rails > 0 {
                p.nic_bands = vec![AlphaBeta::new(nic_alpha, p.inv_rn); rails];
            }
        }
        Ok(p)
    }
}

/// Piecewise (α, β) bands over message size for one (endpoint, locality)
/// pair: `cuts[i]` is the first size *beyond* band `i` (exclusive upper
/// bound), mirroring the inclusive/exclusive protocol switch points of
/// [`MachineParams::cpu_protocol`] and [`MachineParams::gpu_protocol`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MsgTimeTable {
    cuts: [usize; 2],
    n_cuts: usize,
    ab: [AlphaBeta; 3],
}

impl MsgTimeTable {
    /// (α, β) row selected for an `s`-byte message.
    #[inline]
    pub fn ab(&self, s: usize) -> AlphaBeta {
        let mut i = 0;
        while i < self.n_cuts && s >= self.cuts[i] {
            i += 1;
        }
        self.ab[i]
    }

    /// Postal-model time for an `s`-byte message (identical bits to the
    /// branching path).
    #[inline]
    pub fn time(&self, s: usize) -> f64 {
        self.ab(s).time(s)
    }
}

/// The memoized form of [`MachineParams`] used by the simulator hot path
/// ([`crate::sim`]): protocol-band lookup tables per (endpoint, locality),
/// the memcpy classes, and the NIC injection rate. Build one per machine
/// with [`MachineParams::compile`] and share it across cells.
#[derive(Clone, Debug, PartialEq)]
pub struct CompiledParams {
    /// `tables[endpoint][locality]` with endpoint 0 = CPU, 1 = GPU.
    tables: [[MsgTimeTable; 3]; 2],
    memcpy: [[AlphaBeta; 2]; 2],
    /// Inverse NIC injection rate `1/R_N` [s/B].
    pub inv_rn: f64,
    /// Per-rail injection bands (see [`MachineParams::nic_bands`]).
    nic_bands: Vec<AlphaBeta>,
}

impl CompiledParams {
    /// The band table for an (endpoint, locality) pair.
    #[inline]
    pub fn table(&self, ep: Endpoint, l: Locality) -> &MsgTimeTable {
        let ei = match ep {
            Endpoint::Cpu => 0,
            Endpoint::Gpu => 1,
        };
        &self.tables[ei][loc_idx(l)]
    }

    /// Postal-model time for one message — bit-identical to
    /// [`MachineParams::msg_time`].
    #[inline]
    pub fn msg_time(&self, ep: Endpoint, l: Locality, s: usize) -> f64 {
        self.table(ep, l).time(s)
    }

    /// Occupancy one transfer places on a NIC rail — bit-identical to
    /// [`MachineParams::nic_busy`].
    #[inline]
    pub fn nic_busy(&self, rail: usize, bytes: usize) -> f64 {
        let band = self.nic_bands.get(rail).copied().unwrap_or(AlphaBeta::new(0.0, self.inv_rn));
        band.alpha + bytes as f64 * band.beta
    }

    /// Host↔device copy time — bit-identical to
    /// [`MachineParams::memcpy_time`].
    #[inline]
    pub fn memcpy_time(&self, dir: CopyDir, s: usize, nprocs: usize) -> f64 {
        assert!(nprocs >= 1 && nprocs <= 4, "memcpy procs {nprocs} outside measured range 1..=4");
        let row = if nprocs == 1 { 0 } else { 1 };
        let col = match dir {
            CopyDir::H2D => 0,
            CopyDir::D2H => 1,
        };
        self.memcpy[row][col].time(s.div_ceil(nprocs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_spot_checks() {
        let p = lassen_params();
        // CPU short on-socket row of Table 2.
        let ab = p.cpu_ab(Protocol::Short, Locality::OnSocket);
        assert_eq!(ab.alpha, 3.67e-7);
        assert_eq!(ab.beta, 1.32e-10);
        // GPU rendezvous off-node row.
        let ab = p.gpu_ab(Protocol::Rendezvous, Locality::OffNode);
        assert_eq!(ab.alpha, 1.10e-5);
        assert_eq!(ab.beta, 1.72e-10);
    }

    #[test]
    fn protocol_switching() {
        let p = lassen_params();
        assert_eq!(p.cpu_protocol(0), Protocol::Short);
        assert_eq!(p.cpu_protocol(511), Protocol::Short);
        assert_eq!(p.cpu_protocol(512), Protocol::Eager);
        assert_eq!(p.cpu_protocol(8192), Protocol::Eager); // inclusive bound
        assert_eq!(p.cpu_protocol(8193), Protocol::Rendezvous);
        assert_eq!(p.gpu_protocol(100), Protocol::Eager);
        assert_eq!(p.gpu_protocol(1 << 20), Protocol::Rendezvous);
    }

    #[test]
    fn msg_time_monotone_in_size() {
        let p = lassen_params();
        for l in [Locality::OnSocket, Locality::OnNode, Locality::OffNode] {
            for ep in [Endpoint::Cpu, Endpoint::Gpu] {
                // Within a protocol regime, strictly increasing.
                let t1 = p.msg_time(ep, l, 1024);
                let t2 = p.msg_time(ep, l, 4096);
                assert!(t2 > t1, "{ep:?} {l} not monotone");
            }
        }
    }

    #[test]
    fn gpu_latency_dominates_cpu() {
        // Section 4.6: "high overhead for inter-GPU communication
        // on-socket and on-node" — GPU alphas exceed CPU alphas.
        let p = lassen_params();
        for l in [Locality::OnSocket, Locality::OnNode] {
            assert!(p.gpu_ab(Protocol::Rendezvous, l).alpha > p.cpu_ab(Protocol::Rendezvous, l).alpha);
        }
    }

    #[test]
    fn memcpy_classes() {
        let p = lassen_params();
        assert_eq!(p.memcpy_ab(CopyDir::H2D, 1).alpha, 1.30e-5);
        assert_eq!(p.memcpy_ab(CopyDir::D2H, 4).alpha, 1.47e-5);
        // 2-3 procs fall in the multi-proc class.
        assert_eq!(p.memcpy_ab(CopyDir::H2D, 2), p.memcpy_ab(CopyDir::H2D, 4));
    }

    #[test]
    #[should_panic(expected = "outside measured range")]
    fn memcpy_too_many_procs() {
        lassen_params().memcpy_ab(CopyDir::H2D, 5);
    }

    #[test]
    fn memcpy_split_shares_bytes() {
        let p = lassen_params();
        let s = 1 << 22; // 4 MiB: large enough for the 4-proc path to win
        let t1 = p.memcpy_time(CopyDir::D2H, s, 1);
        let t4 = p.memcpy_time(CopyDir::D2H, s, 4);
        // Each of the 4 procs copies s/4 bytes concurrently.
        assert!((t4 - (1.47e-5 + 1.50e-10 * (s as f64 / 4.0))).abs() < 1e-12);
        // For D2H large copies the 1-proc path is still cheaper on Lassen
        // (Table 3: 1.96e-11*s < 1.47e-5 + 1.50e-10*s/4) until huge sizes.
        assert!(t1 < t4 * 4.0);
    }

    #[test]
    fn rn_value() {
        let p = lassen_params();
        assert!((p.rn() - 1.0 / 4.19e-11).abs() / p.rn() < 1e-12);
    }

    #[test]
    fn scaling_preserves_structure() {
        let p = lassen_params();
        let q = p.scaled(0.5, 2.0);
        assert!((q.cpu[0][0].alpha - p.cpu[0][0].alpha * 0.5).abs() < 1e-20);
        assert!((q.cpu[0][0].beta - p.cpu[0][0].beta / 2.0).abs() < 1e-22);
        assert!((q.rn() - p.rn() * 2.0).abs() / q.rn() < 1e-12);
    }

    #[test]
    fn compiled_tables_match_branching_path_bit_for_bit() {
        let p = lassen_params();
        let c = p.compile();
        // straddle every protocol boundary, both sides, both endpoints
        let sizes = [
            0usize, 1, 511, 512, 513, 8191, 8192, 8193, 1 << 14, 1 << 20, 1 << 24,
        ];
        for l in [Locality::OnSocket, Locality::OnNode, Locality::OffNode] {
            for ep in [Endpoint::Cpu, Endpoint::Gpu] {
                for &s in &sizes {
                    assert_eq!(
                        c.msg_time(ep, l, s).to_bits(),
                        p.msg_time(ep, l, s).to_bits(),
                        "{ep:?} {l} {s}"
                    );
                }
            }
        }
        for dir in [CopyDir::H2D, CopyDir::D2H] {
            for np in 1..=4usize {
                for &s in &sizes {
                    assert_eq!(c.memcpy_time(dir, s, np).to_bits(), p.memcpy_time(dir, s, np).to_bits());
                }
            }
        }
        assert_eq!(c.inv_rn, p.inv_rn);
    }

    #[test]
    fn nic_bands_default_to_legacy_injection_bit_for_bit() {
        let p = lassen_params();
        let c = p.compile();
        for bytes in [0usize, 1, 512, 8192, 1 << 20] {
            let legacy = bytes as f64 * p.inv_rn;
            for rail in 0..4 {
                assert_eq!(p.nic_busy(rail, bytes).to_bits(), legacy.to_bits(), "rail {rail} {bytes}B");
                assert_eq!(c.nic_busy(rail, bytes).to_bits(), legacy.to_bits());
            }
        }
    }

    #[test]
    fn explicit_nic_bands_override_and_scale() {
        let mut p = lassen_params();
        p.nic_bands = vec![AlphaBeta::new(1.0e-6, 2.0e-11), AlphaBeta::new(0.0, 4.0e-11)];
        assert!((p.nic_busy(0, 1000) - (1.0e-6 + 2.0e-8)).abs() < 1e-18);
        assert_eq!(p.nic_busy(1, 1000).to_bits(), (1000.0 * 4.0e-11f64).to_bits());
        // rails beyond the table fall back to inv_rn
        assert_eq!(p.nic_busy(7, 1000).to_bits(), (1000.0 * p.inv_rn).to_bits());
        // compile carries the bands
        let c = p.compile();
        assert_eq!(c.nic_busy(0, 1000).to_bits(), p.nic_busy(0, 1000).to_bits());
        // scaled() scales band alphas and rates like every other table
        let q = p.scaled(0.5, 2.0);
        assert!((q.nic_band(0).alpha - 0.5e-6).abs() < 1e-20);
        assert!((q.nic_band(0).beta - 1.0e-11).abs() < 1e-22);
    }

    #[test]
    fn config_reads_nic_bands() {
        let cfg = crate::util::config::Config::parse("[network]\nnic_rails = 4\nnic_alpha = 2.0e-7\n").unwrap();
        let p = MachineParams::from_config(&cfg).unwrap();
        assert_eq!(p.nic_bands.len(), 4);
        assert_eq!(p.nic_band(3).alpha, 2.0e-7);
        assert_eq!(p.nic_band(3).beta, p.inv_rn);
    }

    #[test]
    fn compiled_tables_follow_config_overrides() {
        let cfg = crate::util::config::Config::parse("[network]\neager_max = 4096\n").unwrap();
        let p = MachineParams::from_config(&cfg).unwrap();
        let c = p.compile();
        // the moved eager->rendezvous switch must be baked into the cuts
        for s in [4096usize, 4097] {
            let a = c.msg_time(Endpoint::Cpu, Locality::OffNode, s);
            let b = p.msg_time(Endpoint::Cpu, Locality::OffNode, s);
            assert_eq!(a.to_bits(), b.to_bits(), "{s}");
        }
        assert_ne!(
            c.table(Endpoint::Cpu, Locality::OffNode).ab(4097),
            c.table(Endpoint::Cpu, Locality::OffNode).ab(4096)
        );
    }

    #[test]
    fn config_overrides() {
        let cfg = crate::util::config::Config::parse(
            "[network]\ninv_rn = 2.0e-11\neager_max = 4096\n[cpu.eager]\nalpha_off_node = 1.0e-6\n",
        )
        .unwrap();
        let p = MachineParams::from_config(&cfg).unwrap();
        assert_eq!(p.inv_rn, 2.0e-11);
        assert_eq!(p.eager_max, 4096);
        assert_eq!(p.cpu_ab(Protocol::Eager, Locality::OffNode).alpha, 1.0e-6);
        // untouched values remain Lassen's
        assert_eq!(p.cpu_ab(Protocol::Eager, Locality::OffNode).beta, 3.79e-10);
    }
}
