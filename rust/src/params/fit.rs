//! Parameter fitting — the BenchPress analog (Section 3).
//!
//! The paper derives each (α, β) pair by running ping-pong / node-pong
//! benchmarks for 1000 iterations and applying a linear least-squares fit.
//! We replicate that pipeline against the discrete-event simulator: run the
//! same experiments, fit, and confirm the fitted values round-trip to the
//! constants the simulator was built from. This is also how a user would
//! calibrate `hetcomm` to a *real* machine: feed measured (size, time)
//! samples to [`fit_alpha_beta`].

use crate::params::AlphaBeta;
use crate::util::stats::{linear_fit, r_squared};

/// One measurement: message size in bytes and observed one-way time in
/// seconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sample {
    pub bytes: usize,
    pub seconds: f64,
}

/// Result of a fit: the (α, β) pair and goodness-of-fit.
#[derive(Clone, Copy, Debug)]
pub struct Fit {
    pub ab: AlphaBeta,
    pub r2: f64,
}

/// Least-squares fit of the postal model `T = α + β·s` to samples.
///
/// α is clamped to be non-negative (a negative intercept is a fitting
/// artifact at coarse size grids, never physical).
pub fn fit_alpha_beta(samples: &[Sample]) -> Fit {
    assert!(samples.len() >= 2, "need >= 2 samples to fit");
    let x: Vec<f64> = samples.iter().map(|s| s.bytes as f64).collect();
    let y: Vec<f64> = samples.iter().map(|s| s.seconds).collect();
    let (a, b) = linear_fit(&x, &y);
    let r2 = r_squared(&x, &y, a, b);
    Fit { ab: AlphaBeta::new(a.max(0.0), b.max(0.0)), r2 }
}

/// Fit per-protocol parameters from a size sweep: samples are partitioned at
/// the protocol switch points and fitted independently, exactly as the
/// paper's Table 2 separates short/eager/rendezvous rows.
pub fn fit_protocol_bands(samples: &[Sample], short_max: usize, eager_max: usize) -> [Option<Fit>; 3] {
    let mut bands: [Vec<Sample>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for &s in samples {
        let idx = if s.bytes < short_max {
            0
        } else if s.bytes < eager_max {
            1
        } else {
            2
        };
        bands[idx].push(s);
    }
    let fit_band = |b: &Vec<Sample>| if b.len() >= 2 { Some(fit_alpha_beta(b)) } else { None };
    [fit_band(&bands[0]), fit_band(&bands[1]), fit_band(&bands[2])]
}

/// Estimate the inverse injection rate `1/R_N` from node-pong measurements
/// at high process counts: at saturation, `T ≈ s_node / R_N`, so the slope
/// of time vs node-injected bytes is `1/R_N`.
pub fn fit_inv_rn(samples: &[Sample]) -> f64 {
    let fit = fit_alpha_beta(samples);
    fit.ab.beta
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(ab: AlphaBeta, sizes: &[usize]) -> Vec<Sample> {
        sizes.iter().map(|&s| Sample { bytes: s, seconds: ab.time(s) }).collect()
    }

    #[test]
    fn exact_fit_roundtrips() {
        let truth = AlphaBeta::new(2.44e-6, 3.79e-10);
        let sizes: Vec<usize> = (9..20).map(|e| 1usize << e).collect();
        let fit = fit_alpha_beta(&synth(truth, &sizes));
        assert!((fit.ab.alpha - truth.alpha).abs() / truth.alpha < 1e-9);
        assert!((fit.ab.beta - truth.beta).abs() / truth.beta < 1e-9);
        assert!(fit.r2 > 0.999999);
    }

    #[test]
    fn noisy_fit_close() {
        let truth = AlphaBeta::new(1e-6, 4e-10);
        let sizes: Vec<usize> = (8..22).map(|e| 1usize << e).collect();
        let mut samples = synth(truth, &sizes);
        // 2% deterministic ripple
        for (i, s) in samples.iter_mut().enumerate() {
            s.seconds *= 1.0 + 0.02 * if i % 2 == 0 { 1.0 } else { -1.0 };
        }
        let fit = fit_alpha_beta(&samples);
        assert!((fit.ab.beta - truth.beta).abs() / truth.beta < 0.05);
    }

    #[test]
    fn protocol_bands_split() {
        let short = AlphaBeta::new(3.67e-7, 1.32e-10);
        let eager = AlphaBeta::new(4.61e-7, 7.12e-11);
        let rend = AlphaBeta::new(3.15e-6, 3.40e-11);
        let mut samples = Vec::new();
        for e in 0..24 {
            let s = 1usize << e;
            let ab = if s < 512 { short } else if s < 8192 { eager } else { rend };
            samples.push(Sample { bytes: s, seconds: ab.time(s) });
        }
        let [f0, f1, f2] = fit_protocol_bands(&samples, 512, 8192);
        assert!((f0.unwrap().ab.alpha - short.alpha).abs() / short.alpha < 1e-6);
        assert!((f1.unwrap().ab.beta - eager.beta).abs() / eager.beta < 1e-6);
        assert!((f2.unwrap().ab.beta - rend.beta).abs() / rend.beta < 1e-6);
    }

    #[test]
    fn empty_band_is_none() {
        let samples = vec![
            Sample { bytes: 1 << 14, seconds: 1e-5 },
            Sample { bytes: 1 << 15, seconds: 2e-5 },
        ];
        let [f0, f1, f2] = fit_protocol_bands(&samples, 512, 8192);
        assert!(f0.is_none());
        assert!(f1.is_none());
        assert!(f2.is_some());
    }

    #[test]
    fn negative_alpha_clamped() {
        // Construct data whose LSQ intercept is negative.
        let samples = vec![
            Sample { bytes: 1000, seconds: 1e-7 },
            Sample { bytes: 2000, seconds: 3e-7 },
        ];
        let fit = fit_alpha_beta(&samples);
        assert!(fit.ab.alpha >= 0.0);
    }

    #[test]
    fn inv_rn_recovery() {
        let inv_rn = 4.19e-11;
        let sizes: Vec<usize> = (16..26).map(|e| 1usize << e).collect();
        let samples: Vec<Sample> =
            sizes.iter().map(|&s| Sample { bytes: s, seconds: 5e-6 + inv_rn * s as f64 }).collect();
        let est = fit_inv_rn(&samples);
        assert!((est - inv_rn).abs() / inv_rn < 1e-6);
    }
}
