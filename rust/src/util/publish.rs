//! Epoch-published immutable snapshots: a lock-free pointer-swap cell.
//!
//! [`Published<T>`] holds one current `Arc<T>` snapshot and supports two
//! operations: `load` (the read path — never takes a lock, never blocks on
//! a publisher) and `publish` (the write path — builds happen entirely
//! off-path, then one atomic swap makes the new snapshot current). The
//! offline image vendors no `arc-swap` crate, so this is the same idea in
//! std: two value slots, a `current` index, and a per-slot pin counter
//! that tells publishers when the retired slot's last in-flight reader has
//! left. The advisor's serving layer ([`crate::advisor::service`]) builds
//! its multi-tenant snapshot front end on this cell.
//!
//! Read protocol (`load`): read `current` → pin that slot → re-read
//! `current`; if it still names the pinned slot, clone the `Arc` out and
//! unpin, otherwise unpin and retry (a publish moved `current` mid-read).
//! Publish protocol (under a writer-only mutex): wait for the *non*-current
//! slot's pins to drain, write the new snapshot into it, swing `current`,
//! then drain and empty the old slot so the retired snapshot is dropped as
//! soon as its last reader leaves — readers never observe the teardown.
//!
//! Why the validated pin is sound (every atomic here is `SeqCst`, so all
//! of these operations sit in one total order):
//!
//! - A publisher writes a slot only while it is not current, and only
//!   after its pin drain read 0. If a reader's pin lands *before* the
//!   drain in the total order, the drain sees it and waits; the reader's
//!   validation then fails (the slot it pinned is not current) and it
//!   unpins promptly, so the wait is bounded by one pin/validate/unpin.
//! - If the pin lands *after* the drain, the publisher's earlier
//!   `current` swing is also ordered before the reader's validation read,
//!   which therefore cannot still see the pinned slot as current — the
//!   reader retries instead of touching the slot mid-write.
//! - Hence a reader only dereferences a slot whose value write
//!   happened-before the `current` store it validated against, and no
//!   publisher overwrites a slot while a validated reader is cloning
//!   from it. Re-publication into a previously used slot (the ABA shape)
//!   is covered by the same two cases.
//!
//! `load` is lock-free (it retries only when a publish lands mid-read);
//! `publish` may spin briefly waiting for readers to unpin and serializes
//! with other publishers on a mutex readers never touch.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering::SeqCst};
use std::sync::{Arc, Mutex};

struct Slot<T> {
    /// In-flight readers currently holding this slot pinned.
    pins: AtomicUsize,
    /// The snapshot, present while this slot is current or being retired.
    value: UnsafeCell<Option<Arc<T>>>,
}

impl<T> Slot<T> {
    fn holding(value: Option<Arc<T>>) -> Slot<T> {
        Slot { pins: AtomicUsize::new(0), value: UnsafeCell::new(value) }
    }
}

/// A lock-free, epoch-published snapshot cell (see the module docs).
pub struct Published<T> {
    slots: [Slot<T>; 2],
    /// Index of the slot readers should pin.
    current: AtomicUsize,
    /// Serializes publishers only; the read path never touches it.
    writer: Mutex<()>,
}

// SAFETY: the pin/validate protocol above guarantees a slot's value is
// never written while a validated reader holds it, so sharing Published
// across threads is sound whenever sharing T itself is.
unsafe impl<T: Send + Sync> Send for Published<T> {}
unsafe impl<T: Send + Sync> Sync for Published<T> {}

impl<T> Published<T> {
    /// A cell whose current snapshot is `initial`.
    pub fn new(initial: T) -> Published<T> {
        Published {
            slots: [Slot::holding(Some(Arc::new(initial))), Slot::holding(None)],
            current: AtomicUsize::new(0),
            writer: Mutex::new(()),
        }
    }

    /// The current snapshot. Lock-free: a clone of the published `Arc`,
    /// retried only if a publish swings `current` mid-read.
    pub fn load(&self) -> Arc<T> {
        loop {
            let i = self.current.load(SeqCst);
            let slot = &self.slots[i];
            slot.pins.fetch_add(1, SeqCst);
            if self.current.load(SeqCst) == i {
                // SAFETY: validated pin — the slot's value write
                // happened-before the `current` store just observed, and
                // no publisher writes a pinned slot (module docs).
                let value = unsafe { (*slot.value.get()).clone() }.expect("current slot holds a snapshot");
                slot.pins.fetch_sub(1, SeqCst);
                return value;
            }
            slot.pins.fetch_sub(1, SeqCst);
        }
    }

    /// Publish `next` as the current snapshot. Readers that already hold
    /// the old `Arc` keep it; the old snapshot itself is retired (dropped
    /// from the cell) as soon as its last in-flight reader leaves.
    pub fn publish(&self, next: T) {
        self.publish_arc(Arc::new(next));
    }

    /// [`Published::publish`] for an already-shared snapshot.
    pub fn publish_arc(&self, next: Arc<T>) {
        let _writers = self.writer.lock().expect("publisher mutex poisoned");
        let old = self.current.load(SeqCst);
        let target = 1 - old;
        let slot = &self.slots[target];
        while slot.pins.load(SeqCst) != 0 {
            // stale pins from readers that will fail validation and leave
            std::thread::yield_now();
        }
        // SAFETY: the target slot is not current and its pins drained, so
        // no reader can pass validation on it until `current` swings.
        unsafe { *slot.value.get() = Some(next) };
        self.current.store(target, SeqCst);
        // Eager retirement: once the last reader of the old slot unpins,
        // drop the cell's own reference so the snapshot's lifetime is
        // bounded by its readers, not by the next publish.
        let retired = &self.slots[old];
        while retired.pins.load(SeqCst) != 0 {
            std::thread::yield_now();
        }
        // SAFETY: the old slot is no longer current (validation on it now
        // fails) and its pins drained, so no reader is inside it.
        unsafe { *retired.value.get() = None };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    /// Torn-read canary: every word of the payload must equal the epoch.
    struct Snap {
        epoch: u64,
        payload: Vec<u64>,
    }

    fn snap(epoch: u64) -> Snap {
        Snap { epoch, payload: vec![epoch; 64] }
    }

    #[test]
    fn load_returns_latest_publish() {
        let cell = Published::new(snap(0));
        assert_eq!(cell.load().epoch, 0);
        for e in 1..=5 {
            cell.publish(snap(e));
            assert_eq!(cell.load().epoch, e);
        }
    }

    #[test]
    fn old_snapshot_retired_after_publish() {
        let cell = Published::new(snap(0));
        let held = cell.load();
        cell.publish(snap(1));
        cell.publish(snap(2));
        // the cell dropped its own references to epochs 0 and 1; the only
        // remaining owner of epoch 0 is the reader that loaded it
        assert_eq!(Arc::strong_count(&held), 1);
        assert_eq!(held.epoch, 0, "a held snapshot is immutable across publishes");
        assert_eq!(cell.load().epoch, 2);
    }

    #[test]
    fn concurrent_loads_never_tear_and_epochs_stay_monotone() {
        let cell = Published::new(snap(0));
        let stop = AtomicBool::new(false);
        const PUBLISHES: u64 = 400;
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    let mut last = 0u64;
                    while !stop.load(SeqCst) {
                        let s = cell.load();
                        assert!(s.payload.iter().all(|&w| w == s.epoch), "torn snapshot at epoch {}", s.epoch);
                        assert!(s.epoch >= last, "epoch went backwards: {} after {last}", s.epoch);
                        last = s.epoch;
                    }
                });
            }
            for e in 1..=PUBLISHES {
                cell.publish(snap(e));
            }
            stop.store(true, SeqCst);
        });
        assert_eq!(cell.load().epoch, PUBLISHES);
    }

    #[test]
    fn publishers_serialize_under_contention() {
        let cell = Published::new(snap(0));
        std::thread::scope(|scope| {
            for t in 0..4 {
                scope.spawn(move || {
                    for e in 0..50 {
                        cell.publish(snap(t * 1000 + e));
                    }
                });
            }
        });
        // 200 publishes later the cell still serves exactly one coherent
        // snapshot, and it is one of the published values
        let last = cell.load();
        assert!(last.payload.iter().all(|&w| w == last.epoch));
    }

    #[test]
    fn publish_arc_shares_without_copying() {
        let cell = Published::new(snap(0));
        let shared = Arc::new(snap(7));
        cell.publish_arc(Arc::clone(&shared));
        assert!(Arc::ptr_eq(&cell.load(), &shared));
    }
}
