//! Minimal JSON substrate shared by the versioned artifact layers
//! ([`crate::advisor::persist`], [`crate::trace::persist`]).
//!
//! The offline image vendors no `serde`, so artifacts are written by
//! hand-rolled emitters and read back through this recursive-descent
//! parser — enough for any well-formed JSON value — followed by
//! schema-checked extraction at the call site. Floats are emitted through
//! [`fmt_f64`] (Rust's shortest-round-trip `Display`), so a parsed
//! artifact reproduces the original `f64` bits and emit∘parse∘emit is the
//! identity on artifact bytes.

/// Shortest-round-trip float formatting for artifact emitters. Deliberately
/// NOT a fixed-width format: 10 significant digits cannot round-trip an
/// f64, and artifacts must parse back bit for bit. Non-finite values
/// serialize as `null` (JSON has no infinities).
pub fn fmt_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Integer-list formatting for artifact emitters (`[a, b, c]`), shared by
/// the surface and trace writers so both formats stay in lockstep.
pub fn fmt_usize_list(xs: &[usize]) -> String {
    let items: Vec<String> = xs.iter().map(|v| v.to_string()).collect();
    format!("[{}]", items.join(", "))
}

/// A parsed JSON value (object keys keep file order).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one top-level value and require only whitespace after it.
    pub fn parse(text: &str) -> Result<Json, String> {
        Parser::new(text).parse()
    }

    /// Look a field up in an object value.
    pub fn field(&self, key: &str) -> Result<&Json, String> {
        match self {
            Json::Obj(pairs) => pairs
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .ok_or_else(|| format!("missing field {key:?}")),
            _ => Err(format!("expected an object holding {key:?}")),
        }
    }

    pub fn as_str(&self) -> Result<&str, String> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(format!("expected a string, found {other:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64, String> {
        match self {
            Json::Num(x) => Ok(*x),
            other => Err(format!("expected a number, found {other:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], String> {
        match self {
            Json::Arr(items) => Ok(items),
            other => Err(format!("expected an array, found {other:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize, String> {
        let x = self.as_f64()?;
        if x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64 {
            Ok(x as usize)
        } else {
            Err(format!("expected a non-negative integer, found {x}"))
        }
    }

    pub fn as_usize_list(&self) -> Result<Vec<usize>, String> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(text: &'a str) -> Parser<'a> {
        Parser { bytes: text.as_bytes(), pos: 0 }
    }

    /// Parse one top-level value and require only whitespace after it.
    fn parse(mut self) -> Result<Json, String> {
        let value = self.value()?;
        self.skip_ws();
        if self.pos != self.bytes.len() {
            return Err(format!("trailing content at byte {}", self.pos));
        }
        Ok(value)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii number bytes");
        text.parse::<f64>().map(Json::Num).map_err(|_| format!("invalid number {text:?} at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut raw: Vec<u8> = Vec::new();
        loop {
            let c = self.peek().ok_or("unterminated string")?;
            self.pos += 1;
            match c {
                b'"' => break,
                b'\\' => {
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => raw.push(b'"'),
                        b'\\' => raw.push(b'\\'),
                        b'/' => raw.push(b'/'),
                        b'n' => raw.push(b'\n'),
                        b'r' => raw.push(b'\r'),
                        b't' => raw.push(b'\t'),
                        b'b' => raw.push(0x08),
                        b'f' => raw.push(0x0c),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| "invalid \\u escape".to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| format!("invalid \\u escape {hex:?}"))?;
                            self.pos += 4;
                            let ch = char::from_u32(code).ok_or_else(|| format!("invalid codepoint {code:#x}"))?;
                            let mut buf = [0u8; 4];
                            raw.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                        }
                        other => return Err(format!("unknown escape \\{}", other as char)),
                    }
                }
                other => raw.push(other),
            }
        }
        String::from_utf8(raw).map_err(|_| "invalid UTF-8 in string".to_string())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        self.skip_ws();
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    break;
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
        Ok(Json::Arr(items))
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        self.skip_ws();
        let mut pairs = Vec::new();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            pairs.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    break;
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
        Ok(Json::Obj(pairs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parser_handles_general_values() {
        let v = Json::parse(" { \"a\": [1, -2.5e3, true, false, null], \"b\\n\": \"x\\u0041\" } ").unwrap();
        let a = v.field("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), 1.0);
        assert_eq!(a[1].as_f64().unwrap(), -2500.0);
        assert_eq!(a[2], Json::Bool(true));
        assert_eq!(a[4], Json::Null);
        assert_eq!(v.field("b\n").unwrap().as_str().unwrap(), "xA");
        assert!(v.field("a").unwrap().as_usize_list().is_err(), "floats are not usizes");
    }

    #[test]
    fn malformed_inputs_rejected() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("{\"a\": 1} trailing").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn float_display_roundtrips() {
        for x in [1.0, 2.44e-6, 3.79e-10, 0.25, 123456.789, 4.19e-11] {
            let shown = fmt_f64(x);
            assert_eq!(shown.parse::<f64>().unwrap().to_bits(), x.to_bits(), "{shown}");
        }
        assert_eq!(fmt_f64(f64::INFINITY), "null");
    }

    #[test]
    fn usize_extraction_bounds() {
        assert_eq!(Json::parse("4294967295").unwrap().as_usize().unwrap(), u32::MAX as usize);
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }
}
