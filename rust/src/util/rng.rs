//! Deterministic, seedable PRNG (xoshiro256** by Blackman & Vigna).
//!
//! The offline image vendors no `rand` crate, and determinism matters here
//! anyway: matrix generators, pattern generators and property tests must be
//! reproducible run-to-run so EXPERIMENTS.md numbers are stable.

/// Deterministic per-item sub-seed (splitmix-style index mixing): derives a
/// well-spread seed for work item `index` from a base seed. Shared by the
/// sweep engine's per-cell generators and the perf harness so both draw the
/// same pattern for the same (seed, cell).
pub fn index_seed(base: u64, index: usize) -> u64 {
    let mut z = base ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^ (z >> 31)
}

/// xoshiro256** state. Not cryptographic; excellent statistical quality for
/// simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via splitmix64 expansion
    /// (the canonical seeding procedure for xoshiro).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        // splitmix64 never yields all-zero state from distinct outputs, but
        // guard anyway: xoshiro must not be seeded with all zeros.
        let s = if s == [0, 0, 0, 0] { [1, 2, 3, 4] } else { s };
        Rng { s }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method to
    /// avoid modulo bias.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.gen_range((j + 1) as u64) as usize;
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// Approximately normal draw via sum of 12 uniforms (Irwin–Hall),
    /// adequate for jitter in synthetic workloads.
    pub fn normal(&mut self, mean: f64, sd: f64) -> f64 {
        let s: f64 = (0..12).map(|_| self.f64()).sum();
        mean + sd * (s - 6.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1000, u64::MAX] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn gen_range_covers_small_domain() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(11);
        for _ in 0..50 {
            let k = r.usize_in(0, 20);
            let s = r.sample_indices(50, k);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean {mean}");
        assert!((var - 4.0).abs() < 0.3, "var {var}");
    }
}
