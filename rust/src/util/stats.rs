//! Descriptive statistics over timing samples, plus linear least squares —
//! the fitting procedure the paper uses to turn ping-pong measurements into
//! the α/β parameters of Tables 2–4.

/// Summary statistics for a sample of (timing) values.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute summary statistics. Panics on an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let mut xs = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        Summary {
            n,
            min: xs[0],
            max: xs[n - 1],
            mean,
            median: percentile_sorted(&xs, 50.0),
            p95: percentile_sorted(&xs, 95.0),
            stddev: var.sqrt(),
        }
    }
}

/// Percentile of an already-sorted slice using linear interpolation.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least-squares fit `y = a + b*x`, returning `(a, b)`.
///
/// This is the "linear least-squares fit to the collected data" that produces
/// each α/β pair in Section 3: `x` is message size in bytes, `y` is measured
/// time, `a` is latency α, `b` is per-byte cost β.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2, "need >= 2 points to fit a line");
    let n = x.len() as f64;
    let sx: f64 = x.iter().sum();
    let sy: f64 = y.iter().sum();
    let sxx: f64 = x.iter().map(|v| v * v).sum();
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| a * b).sum();
    let denom = n * sxx - sx * sx;
    assert!(denom.abs() > f64::EPSILON, "degenerate x values");
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

/// Coefficient of determination R² for a linear fit.
pub fn r_squared(x: &[f64], y: &[f64], a: f64, b: f64) -> f64 {
    let mean_y = y.iter().sum::<f64>() / y.len() as f64;
    let ss_tot: f64 = y.iter().map(|v| (v - mean_y).powi(2)).sum();
    let ss_res: f64 = x.iter().zip(y).map(|(xi, yi)| (yi - (a + b * xi)).powi(2)).sum();
    if ss_tot == 0.0 {
        1.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Geometric mean (used for cross-matrix speedup aggregation in reports).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[2.5]);
        assert_eq!(s.median, 2.5);
        assert_eq!(s.p95, 2.5);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 50.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn linear_fit_exact() {
        // y = 3 + 2x exactly.
        let x = [1.0, 2.0, 3.0, 4.0];
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.0 * v).collect();
        let (a, b) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r_squared(&x, &y, a, b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_recovers_alpha_beta_scale() {
        // Postal-model-like data: alpha=2e-6 s, beta=4e-10 s/B over byte
        // sizes spanning the paper's range.
        let x: Vec<f64> = (0..20).map(|i| (1u64 << i) as f64).collect();
        let y: Vec<f64> = x.iter().map(|s| 2e-6 + 4e-10 * s).collect();
        let (a, b) = linear_fit(&x, &y);
        assert!((a - 2e-6).abs() / 2e-6 < 1e-9);
        assert!((b - 4e-10).abs() / 4e-10 < 1e-9);
    }

    #[test]
    fn geomean_of_constant() {
        assert!((geomean(&[3.0, 3.0, 3.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }
}
