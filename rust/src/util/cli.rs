//! Declarative command-line flag parser (no `clap` in the offline image).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and auto-generated `--help` text. Used by the `hetcomm`
//! launcher and every example binary.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one flag.
#[derive(Clone, Debug)]
struct FlagSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_bool: bool,
}

/// A declarative CLI parser: register flags, then [`Cli::parse`].
#[derive(Debug, Default)]
pub struct Cli {
    program: String,
    about: &'static str,
    flags: Vec<FlagSpec>,
    positional_help: Vec<(&'static str, &'static str)>,
}

/// Parsed argument values.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

/// CLI parse error with a user-facing message.
#[derive(Debug, thiserror::Error)]
#[error("{0}")]
pub struct CliError(pub String);

impl Cli {
    pub fn new(program: &str, about: &'static str) -> Self {
        Cli { program: program.to_string(), about, ..Default::default() }
    }

    /// Register a string-valued flag with a default.
    pub fn flag(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: Some(default.to_string()), is_bool: false });
        self
    }

    /// Register a required string-valued flag (no default).
    pub fn required(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, is_bool: false });
        self
    }

    /// Register a boolean switch (defaults to false).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, is_bool: true });
        self
    }

    /// Document a positional argument (for help text only).
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positional_help.push((name, help));
        self
    }

    /// Render `--help` text.
    pub fn help_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{} — {}\n", self.program, self.about);
        let _ = write!(out, "USAGE: {} [FLAGS]", self.program);
        for (name, _) in &self.positional_help {
            let _ = write!(out, " <{name}>");
        }
        let _ = writeln!(out, "\n\nFLAGS:");
        for f in &self.flags {
            let meta = if f.is_bool {
                String::new()
            } else if let Some(d) = &f.default {
                format!(" <val> [default: {d}]")
            } else {
                " <val> [required]".to_string()
            };
            let _ = writeln!(out, "  --{}{}\n        {}", f.name, meta, f.help);
        }
        let _ = writeln!(out, "  --help\n        Print this help text");
        if !self.positional_help.is_empty() {
            let _ = writeln!(out, "\nARGS:");
            for (name, help) in &self.positional_help {
                let _ = writeln!(out, "  <{name}>  {help}");
            }
        }
        out
    }

    /// Parse an argv slice (without the program name). Returns an error whose
    /// message is the help text when `--help` is present.
    pub fn parse<S: AsRef<str>>(&self, argv: &[S]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for f in &self.flags {
            if f.is_bool {
                args.bools.insert(f.name.to_string(), false);
            } else if let Some(d) = &f.default {
                args.values.insert(f.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = argv[i].as_ref();
            if tok == "--help" || tok == "-h" {
                return Err(CliError(self.help_text()));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError(format!("unknown flag --{name}\n\n{}", self.help_text())))?;
                if spec.is_bool {
                    match inline_val.as_deref() {
                        None | Some("true") => {
                            args.bools.insert(name.to_string(), true);
                        }
                        Some("false") => {
                            args.bools.insert(name.to_string(), false);
                        }
                        Some(v) => return Err(CliError(format!("--{name} takes no value, got {v:?}"))),
                    }
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .map(|s| s.as_ref().to_string())
                                .ok_or_else(|| CliError(format!("--{name} requires a value")))?
                        }
                    };
                    args.values.insert(name.to_string(), val);
                }
            } else {
                args.positional.push(tok.to_string());
            }
            i += 1;
        }
        for f in &self.flags {
            if !f.is_bool && f.default.is_none() && !args.values.contains_key(f.name) {
                return Err(CliError(format!("missing required flag --{}", f.name)));
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()` and exit with help/error messages on failure.
    pub fn parse_env(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{}", e.0);
                std::process::exit(if e.0.contains("USAGE:") && !e.0.contains("unknown") { 0 } else { 2 });
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or_else(|| panic!("flag --{name} not registered"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        *self.bools.get(name).unwrap_or_else(|| panic!("switch --{name} not registered"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected an integer, got {:?}", self.get(name))))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected an integer, got {:?}", self.get(name))))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected a number, got {:?}", self.get(name))))
    }

    /// Parse a comma-separated list of integers, supporting `a:b:c` range
    /// syntax (start:stop:step, stop exclusive) and `2^k` powers.
    pub fn get_usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        let raw = self.get(name);
        let mut out = Vec::new();
        for part in raw.split(',').filter(|p| !p.is_empty()) {
            if let Some((lo, rest)) = part.split_once(':') {
                let (hi, step) = rest.split_once(':').unwrap_or((rest, "1"));
                let (lo, hi, step): (usize, usize, usize) = (
                    lo.parse().map_err(|_| CliError(format!("bad range start {lo:?}")))?,
                    hi.parse().map_err(|_| CliError(format!("bad range stop {hi:?}")))?,
                    step.parse().map_err(|_| CliError(format!("bad range step {step:?}")))?,
                );
                if step == 0 {
                    return Err(CliError("range step must be > 0".into()));
                }
                let mut v = lo;
                while v < hi {
                    out.push(v);
                    v += step;
                }
            } else if let Some(exp) = part.strip_prefix("2^") {
                let e: u32 = exp.parse().map_err(|_| CliError(format!("bad power {part:?}")))?;
                out.push(1usize << e);
            } else {
                out.push(part.parse().map_err(|_| CliError(format!("bad integer {part:?}")))?);
            }
        }
        if out.is_empty() {
            return Err(CliError(format!("--{name}: empty list")));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .flag("size", "8", "message size")
            .required("matrix", "matrix name")
            .switch("verbose", "log more")
    }

    #[test]
    fn defaults_and_required() {
        let a = cli().parse(&["--matrix", "audikw_1"]).unwrap();
        assert_eq!(a.get("size"), "8");
        assert_eq!(a.get("matrix"), "audikw_1");
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn equals_syntax_and_switch() {
        let a = cli().parse(&["--matrix=x", "--size=1024", "--verbose"]).unwrap();
        assert_eq!(a.get_usize("size").unwrap(), 1024);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse(&["--size", "4"]).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        let e = cli().parse(&["--matrix", "m", "--bogus", "1"]).unwrap_err();
        assert!(e.0.contains("unknown flag"));
    }

    #[test]
    fn positional_collected() {
        let a = cli().parse(&["--matrix", "m", "pos1", "pos2"]).unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn help_is_error_with_usage() {
        let e = cli().parse(&["--help"]).unwrap_err();
        assert!(e.0.contains("USAGE"));
        assert!(e.0.contains("--matrix"));
    }

    #[test]
    fn list_parsing() {
        let c = Cli::new("t", "x").flag("sizes", "1,2:8:2,2^10", "sizes");
        let a = c.parse::<&str>(&[]).unwrap();
        assert_eq!(a.get_usize_list("sizes").unwrap(), vec![1, 2, 4, 6, 1024]);
    }

    #[test]
    fn bool_explicit_values() {
        let c = Cli::new("t", "x").switch("on", "sw");
        assert!(c.parse(&["--on=true"]).unwrap().get_bool("on"));
        assert!(!c.parse(&["--on=false"]).unwrap().get_bool("on"));
        assert!(c.parse(&["--on=maybe"]).is_err());
    }
}
