//! Property-testing mini-framework (no `proptest` in the offline image).
//!
//! A property is a function from a [`Gen`]-drawn case to `Result<(), String>`.
//! [`check`] runs many random cases; on failure it attempts greedy shrinking
//! via a user-supplied shrinker before reporting the minimal failing case.
//!
//! ```no_run
//! use hetcomm::util::prop::{check, Gen};
//! check("sort idempotent", 200, |g| {
//!     let mut v = g.vec_usize(0..50, 0, 100);
//!     v.sort_unstable();
//!     let w = { let mut w = v.clone(); w.sort_unstable(); w };
//!     if v == w { Ok(()) } else { Err(format!("{v:?} != {w:?}")) }
//! });
//! ```

use crate::util::rng::Rng;

/// Case generator handed to each property invocation. Wraps a deterministic
/// PRNG whose seed is derived from the run seed and case index, so failures
/// are reproducible from the printed seed.
pub struct Gen {
    rng: Rng,
    /// Seed of this particular case (printed on failure).
    pub case_seed: u64,
}

impl Gen {
    pub fn new(case_seed: u64) -> Self {
        Gen { rng: Rng::new(case_seed), case_seed }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// usize uniform in `[lo, hi)`.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.usize_in(lo, hi)
    }

    /// u64 uniform in `[0, n)`.
    pub fn u64(&mut self, n: u64) -> u64 {
        self.rng.gen_range(n)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_in(lo, hi)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Vector of usizes: length in `len` range, elements in `[lo, hi)`.
    pub fn vec_usize(&mut self, len: std::ops::Range<usize>, lo: usize, hi: usize) -> Vec<usize> {
        let n = self.usize(len.start, len.end.max(len.start + 1));
        (0..n).map(|_| self.usize(lo, hi)).collect()
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.usize(0, xs.len())]
    }

    /// Byte-size magnitudes spanning the paper's sweep range
    /// (1 B … 1 MiB), log-uniform so small and large messages are equally
    /// likely — matches how the figures sample sizes.
    pub fn msg_size(&mut self) -> usize {
        let exp = self.usize(0, 21); // 2^0 .. 2^20
        let base = 1usize << exp;
        // jitter within the octave so we don't only test powers of two
        base + self.usize(0, base.max(1))
    }
}

/// Run `n` random cases of `prop`. Panics with diagnostics on failure.
///
/// The environment variable `HETCOMM_PROP_SEED` overrides the run seed for
/// reproducing failures.
pub fn check<F>(name: &str, n: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let run_seed = std::env::var("HETCOMM_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..n {
        let case_seed = run_seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut g = Gen::new(case_seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed on case {case}/{n} (HETCOMM_PROP_SEED={run_seed}, case_seed={case_seed}):\n  {msg}"
            );
        }
    }
}

/// Run `n` cases of a property over values produced by `make` and checked by
/// `test`, shrinking a failing value with `shrink` (returns simpler
/// candidates) before panicking with the minimal case found.
pub fn check_shrink<T, FM, FT, FS>(name: &str, n: usize, mut make: FM, mut test: FT, shrink: FS)
where
    T: Clone + std::fmt::Debug,
    FM: FnMut(&mut Gen) -> T,
    FT: FnMut(&T) -> Result<(), String>,
    FS: Fn(&T) -> Vec<T>,
{
    let run_seed = std::env::var("HETCOMM_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..n {
        let case_seed = run_seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut g = Gen::new(case_seed);
        let value = make(&mut g);
        if let Err(first_msg) = test(&value) {
            // Greedy shrink: repeatedly take the first simpler candidate that
            // still fails, up to a bounded number of steps.
            let mut cur = value;
            let mut msg = first_msg;
            'outer: for _ in 0..200 {
                for cand in shrink(&cur) {
                    if let Err(m) = test(&cand) {
                        cur = cand;
                        msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property {name:?} failed on case {case}/{n} (HETCOMM_PROP_SEED={run_seed}):\n  minimal case: {cur:?}\n  {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", 100, |g| {
            let v = g.vec_usize(0..20, 0, 1000);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if v == w { Ok(()) } else { Err("mismatch".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics() {
        check("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn shrink_finds_small_case() {
        // Property "all values < 10" fails; shrinker should walk toward 10.
        let result = std::panic::catch_unwind(|| {
            check_shrink(
                "lt ten",
                100,
                |g| g.usize(0, 1000),
                |&v| if v < 10 { Ok(()) } else { Err(format!("{v} >= 10")) },
                |&v| if v > 10 { vec![v / 2, v - 1] } else { vec![] },
            );
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("minimal case: 10"), "got: {msg}");
    }

    #[test]
    fn msg_size_spans_range() {
        let mut g = Gen::new(1);
        let sizes: Vec<usize> = (0..500).map(|_| g.msg_size()).collect();
        assert!(sizes.iter().any(|&s| s < 16));
        assert!(sizes.iter().any(|&s| s > 100_000));
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(99);
        let mut b = Gen::new(99);
        for _ in 0..50 {
            assert_eq!(a.usize(0, 1 << 20), b.usize(0, 1 << 20));
        }
    }
}
