//! In-tree substrates for the offline build environment: deterministic PRNG,
//! CLI flag parsing, INI-style config files, a minimal JSON parser for the
//! versioned artifact layers, descriptive statistics, a property-testing
//! mini-framework, a deterministic fan-out worker pool, a lock-free
//! snapshot-publication cell, and a tiny logger.

pub mod cli;
pub mod config;
pub mod json;
pub mod logging;
pub mod pool;
pub mod prop;
pub mod publish;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;
