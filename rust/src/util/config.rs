//! INI-style configuration files (no `serde` in the offline image).
//!
//! Machine descriptions and run configurations live in `configs/*.cfg`:
//!
//! ```text
//! # comment
//! [machine]
//! name = lassen
//! sockets_per_node = 2
//! gpus_per_socket = 2
//! cores_per_socket = 20
//! ```
//!
//! Sections map to [`Section`]s; values are typed on access.

use std::collections::BTreeMap;
use std::path::Path;

/// A parsed configuration: ordered sections of key → value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    sections: BTreeMap<String, Section>,
}

/// One `[section]` of key/value pairs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Section {
    values: BTreeMap<String, String>,
}

/// Configuration parse/access error.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("line {line}: {msg}")]
    Parse { line: usize, msg: String },
    #[error("missing section [{0}]")]
    MissingSection(String),
    #[error("missing key {key} in section [{section}]")]
    MissingKey { section: String, key: String },
    #[error("key {key}: cannot parse {value:?} as {ty}")]
    BadValue { key: String, value: String, ty: &'static str },
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl Config {
    /// Parse configuration text.
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let mut current = String::from("default");
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            let lineno = i + 1;
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body.strip_suffix(']').ok_or(ConfigError::Parse {
                    line: lineno,
                    msg: format!("unterminated section header {line:?}"),
                })?;
                if name.trim().is_empty() {
                    return Err(ConfigError::Parse { line: lineno, msg: "empty section name".into() });
                }
                current = name.trim().to_string();
                cfg.sections.entry(current.clone()).or_default();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or(ConfigError::Parse {
                line: lineno,
                msg: format!("expected key = value, got {line:?}"),
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ConfigError::Parse { line: lineno, msg: "empty key".into() });
            }
            // Strip trailing inline comments.
            let value = match value.find('#') {
                Some(pos) => &value[..pos],
                None => value,
            };
            cfg.sections
                .entry(current.clone())
                .or_default()
                .values
                .insert(key.to_string(), value.trim().to_string());
        }
        Ok(cfg)
    }

    /// Load and parse a config file.
    pub fn load(path: impl AsRef<Path>) -> Result<Config, ConfigError> {
        Ok(Config::parse(&std::fs::read_to_string(path)?)?)
    }

    /// Fetch a section, erroring if absent.
    pub fn section(&self, name: &str) -> Result<&Section, ConfigError> {
        self.sections.get(name).ok_or_else(|| ConfigError::MissingSection(name.to_string()))
    }

    /// Fetch a section if present.
    pub fn section_opt(&self, name: &str) -> Option<&Section> {
        self.sections.get(name)
    }

    /// All section names, sorted.
    pub fn section_names(&self) -> Vec<&str> {
        self.sections.keys().map(|s| s.as_str()).collect()
    }

    /// Serialize back to text (round-trip capable modulo comments/order).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for (name, sec) in &self.sections {
            out.push_str(&format!("[{name}]\n"));
            for (k, v) in &sec.values {
                out.push_str(&format!("{k} = {v}\n"));
            }
            out.push('\n');
        }
        out
    }
}

impl Section {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    fn require(&self, section: &str, key: &str) -> Result<&str, ConfigError> {
        self.get(key).ok_or_else(|| ConfigError::MissingKey { section: section.to_string(), key: key.to_string() })
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize(&self, section: &str, key: &str) -> Result<usize, ConfigError> {
        let v = self.require(section, key)?;
        v.parse().map_err(|_| ConfigError::BadValue { key: key.into(), value: v.into(), ty: "usize" })
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ConfigError::BadValue { key: key.into(), value: v.into(), ty: "usize" }),
        }
    }

    pub fn f64(&self, section: &str, key: &str) -> Result<f64, ConfigError> {
        let v = self.require(section, key)?;
        v.parse().map_err(|_| ConfigError::BadValue { key: key.into(), value: v.into(), ty: "f64" })
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ConfigError::BadValue { key: key.into(), value: v.into(), ty: "f64" }),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(ConfigError::BadValue { key: key.into(), value: v.into(), ty: "bool" }),
        }
    }

    /// Insert a value (used by config writers/tests).
    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.values.insert(key.to_string(), value.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# machine description
[machine]
name = lassen
sockets_per_node = 2   # two Power9s
gpus_per_socket = 2

[run]
iters = 1000
warmup = true
cap = 8192.5
"#;

    #[test]
    fn parses_sections_and_values() {
        let c = Config::parse(SAMPLE).unwrap();
        let m = c.section("machine").unwrap();
        assert_eq!(m.get("name"), Some("lassen"));
        assert_eq!(m.usize("machine", "sockets_per_node").unwrap(), 2);
        let r = c.section("run").unwrap();
        assert_eq!(r.usize("run", "iters").unwrap(), 1000);
        assert!(r.bool_or("warmup", false).unwrap());
        assert_eq!(r.f64("run", "cap").unwrap(), 8192.5);
    }

    #[test]
    fn inline_comment_stripped() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.section("machine").unwrap().usize("machine", "sockets_per_node").unwrap(), 2);
    }

    #[test]
    fn missing_section_and_key() {
        let c = Config::parse(SAMPLE).unwrap();
        assert!(matches!(c.section("nope"), Err(ConfigError::MissingSection(_))));
        assert!(matches!(
            c.section("machine").unwrap().usize("machine", "nope"),
            Err(ConfigError::MissingKey { .. })
        ));
    }

    #[test]
    fn defaults() {
        let c = Config::parse(SAMPLE).unwrap();
        let m = c.section("machine").unwrap();
        assert_eq!(m.usize_or("missing", 7).unwrap(), 7);
        assert_eq!(m.str_or("missing", "x"), "x");
        assert_eq!(m.f64_or("missing", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn bad_syntax_reports_line() {
        let err = Config::parse("[machine]\nnot_a_kv_line\n").unwrap_err();
        match err {
            ConfigError::Parse { line, .. } => assert_eq!(line, 2),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn bad_value_type() {
        let c = Config::parse("[a]\nx = hello\n").unwrap();
        assert!(matches!(c.section("a").unwrap().usize("a", "x"), Err(ConfigError::BadValue { .. })));
    }

    #[test]
    fn round_trip() {
        let c = Config::parse(SAMPLE).unwrap();
        let c2 = Config::parse(&c.to_text()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn unterminated_section_errors() {
        assert!(Config::parse("[machine\n").is_err());
    }
}
