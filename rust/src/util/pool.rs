//! Deterministic fan-out/collect worker pool.
//!
//! One generic helper serves every parallel evaluation loop in the crate
//! ([`crate::sweep::run_sweep`], [`crate::sweep::run_sweep_trace`], the
//! advisor's batched queries, the `hetcomm perf` harness): work items
//! `0..n` are claimed dynamically off a shared atomic counter, each worker
//! owns a reusable per-thread state (simulation scratch buffers, …), and
//! results land in a **pre-sized per-item slot vector** — aggregation is
//! O(n) with no lock contention on the hot loop and no post-hoc sort.
//!
//! Determinism contract: `f(state, i)` must depend only on `i` (plus
//! deterministic seeds derived from it); then the returned vector is
//! identical for any thread count or scheduling order.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Resolve the worker count: 0 = available parallelism, always clamped to
/// `[1, work_items]`.
pub fn effective_threads(requested: usize, work_items: usize) -> usize {
    let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t = if requested == 0 { auto } else { requested };
    t.clamp(1, work_items.max(1))
}

/// Evaluate `f(state, i)` for every `i in 0..n` over `threads` workers
/// (callers usually pass an [`effective_threads`] result), giving each
/// worker one `init()`-created state reused across its items. Results come
/// back in index order regardless of scheduling.
pub fn map_with<S, T, FS, F>(n: usize, threads: usize, init: FS, f: F) -> Vec<T>
where
    T: Send,
    FS: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    let next = AtomicUsize::new(0);
    // Pre-sized slot per work item: each index is written exactly once, by
    // whichever worker claimed it, via the owning thread's local batch.
    let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut state = init();
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(&mut state, i)));
                    }
                    local
                })
            })
            .collect();
        for handle in handles {
            for (i, value) in handle.join().expect("pool worker panicked") {
                slots[i] = Some(value);
            }
        }
    });
    slots.into_iter().map(|s| s.expect("every index evaluated exactly once")).collect()
}

/// Stateless convenience over [`map_with`].
pub fn map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    map_with(n, threads, || (), |_, i| f(i))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_index_order_any_thread_count() {
        for threads in [1, 2, 7, 64] {
            let out = map(100, threads, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn per_worker_state_reused() {
        // each worker counts its own items; the counts must partition n
        let counts = map_with(50, 4, || 0usize, |state, _i| {
            *state += 1;
            *state
        });
        assert_eq!(counts.len(), 50);
        assert!(counts.iter().all(|&c| c >= 1));
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = map(0, 8, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn effective_threads_clamps() {
        assert_eq!(effective_threads(3, 100), 3);
        assert_eq!(effective_threads(64, 2), 2);
        assert!(effective_threads(0, 100) >= 1);
        assert_eq!(effective_threads(0, 0), 1);
    }
}
