//! Property tests for the Algorithm 1 machinery (chunking, rank
//! assignment) and the Split exchange plan.

use hetcomm::comm::plan::{assign_ranks, split_chunks};
use hetcomm::comm::{Strategy, StrategyKind, Transport};
use hetcomm::coordinator::ExchangePlan;
use hetcomm::sparse::{gen, PartitionedMatrix};
use hetcomm::topology::machines::lassen;
use hetcomm::topology::NodeId;
use hetcomm::util::prop::{check, Gen};
use std::collections::BTreeMap;

fn random_vols(g: &mut Gen, max_dests: usize) -> BTreeMap<NodeId, usize> {
    let n = g.usize(1, max_dests + 1);
    let mut vols = BTreeMap::new();
    for i in 0..n {
        vols.insert(NodeId(i + 1), g.usize(0, 1 << 18));
    }
    vols
}

#[test]
fn chunks_conserve_bytes() {
    check("split_chunks conserves volume", 200, |g| {
        let vols = random_vols(g, 8);
        let cap = *g.choose(&[512usize, 4096, 8192, 65536]);
        let ppn = *g.choose(&[4usize, 16, 40]);
        let chunks = split_chunks(NodeId(0), &vols, cap, ppn);
        let total: usize = vols.values().sum();
        let got: usize = chunks.iter().map(|c| c.bytes).sum();
        if got != total {
            return Err(format!("chunks {got} != total {total}"));
        }
        Ok(())
    });
}

#[test]
fn chunk_count_bounded_after_raise() {
    check("chunk count <= max(ppn, dests)", 200, |g| {
        let vols = random_vols(g, 8);
        let cap = *g.choose(&[512usize, 8192]);
        let ppn = *g.choose(&[4usize, 40]);
        let chunks = split_chunks(NodeId(0), &vols, cap, ppn);
        let total: usize = vols.values().sum();
        let max_single = vols.values().copied().max().unwrap_or(0);
        if max_single < cap {
            // conglomeration: exactly one chunk per nonzero destination
            let nonzero = vols.values().filter(|&&v| v > 0).count();
            if chunks.len() != nonzero {
                return Err(format!("conglomerated {} != {nonzero}", chunks.len()));
            }
        } else if total.div_ceil(cap) > ppn {
            // raised cap: per-destination splitting adds at most one
            // remainder chunk per destination
            let bound = ppn + vols.len();
            if chunks.len() > bound {
                return Err(format!("{} chunks > bound {bound}", chunks.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn chunks_respect_effective_cap() {
    check("each chunk <= effective cap", 200, |g| {
        let vols = random_vols(g, 6);
        let cap = *g.choose(&[1024usize, 8192]);
        let ppn = 40;
        let total: usize = vols.values().sum();
        let max_single = vols.values().copied().max().unwrap_or(0);
        let eff = if max_single < cap {
            usize::MAX // conglomerated: one message per node, any size
        } else if total.div_ceil(cap) > ppn {
            total.div_ceil(ppn)
        } else {
            cap
        };
        for c in split_chunks(NodeId(0), &vols, cap, ppn) {
            if c.bytes > eff {
                return Err(format!("chunk {} > effective cap {eff}", c.bytes));
            }
        }
        Ok(())
    });
}

#[test]
fn rank_assignment_descending_and_bounded() {
    check("assign_ranks: ranks < ppn, big chunks get extreme ranks", 200, |g| {
        let n = g.usize(1, 50);
        let sizes: Vec<usize> = (0..n).map(|_| g.usize(0, 1 << 16)).collect();
        let ppn = *g.choose(&[1usize, 4, 16, 40]);
        for from_front in [true, false] {
            let ranks = assign_ranks(&sizes, ppn, from_front);
            if ranks.len() != sizes.len() {
                return Err("length mismatch".into());
            }
            if ranks.iter().any(|&r| r >= ppn) {
                return Err(format!("rank out of range: {ranks:?}"));
            }
            // the largest chunk gets rank 0 (front) or ppn-1 (back)
            if let Some(imax) = (0..n).max_by_key(|&i| (sizes[i], std::cmp::Reverse(i))) {
                let expect = if from_front { 0 } else { ppn - 1 };
                if ranks[imax] != expect {
                    return Err(format!("largest chunk rank {} != {expect}", ranks[imax]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn split_plan_validates_on_random_stencils() {
    check("split exchange plan delivers every ghost", 12, |g| {
        let nx = g.usize(3, 7);
        let ny = g.usize(3, 7);
        let nz = g.usize(3, 7);
        let a = gen::stencil_27pt(nx, ny, nz);
        let nparts = *g.choose(&[2usize, 4, 8]);
        if a.nrows < nparts {
            return Ok(());
        }
        let machine = lassen(2);
        let pm = PartitionedMatrix::build(&a, nparts);
        for kind in [StrategyKind::SplitMd, StrategyKind::SplitDd] {
            let cap = *g.choose(&[64usize, 256, 8192]);
            let s = Strategy::new(kind, Transport::Staged).unwrap().with_cap(cap);
            let plan = ExchangePlan::build(&pm, &machine, s);
            plan.validate(&pm).map_err(|e| format!("{kind:?} cap {cap}: {e}"))?;
        }
        Ok(())
    });
}
