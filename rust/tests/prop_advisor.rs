//! Advisor properties: interpolated surface lookups reproduce the direct
//! Table 6 model evaluation bit for bit on lattice points, off-lattice
//! queries stay inside their regime line's time envelope, the batched
//! interpolator agrees with single-query lookups bit for bit, and the
//! quantized v3 encoding round-trips surfaces losslessly.

use hetcomm::advisor::{persist, DecisionSurface, Pattern, SurfaceAxes};
use hetcomm::model::StrategyModel;
use hetcomm::pattern::generators::Scenario;
use hetcomm::topology::machines;
use hetcomm::util::prop::{check, Gen};

// frontier-4nic exercises the shape-keyed path: its surfaces compile at 4
// rails and the direct model gets the same shape through `with_shape`
const MACHINES: [&str; 4] = ["lassen", "frontier-like", "frontier-4nic", "delta-like"];

/// Small random strictly-ascending axes within the characterization ranges.
fn random_axes(g: &mut Gen) -> SurfaceAxes {
    fn pick(g: &mut Gen, pool: &[usize], n: usize) -> Vec<usize> {
        let mut vals: Vec<usize> = Vec::new();
        while vals.len() < n {
            let v = *g.choose(pool);
            if !vals.contains(&v) {
                vals.push(v);
            }
        }
        vals.sort_unstable();
        vals
    }
    let (nm, ns, nd, ng) = (g.usize(2, 4), g.usize(3, 5), g.usize(1, 3), g.usize(1, 3));
    SurfaceAxes {
        msgs: pick(g, &[16, 32, 64, 128, 256, 512], nm),
        sizes: pick(g, &[1 << 4, 1 << 8, 1 << 10, 1 << 12, 1 << 14, 1 << 18, 1 << 20], ns),
        dest_nodes: pick(g, &[2, 4, 8, 16], nd),
        gpus_per_node: pick(g, &[2, 4, 8], ng),
    }
}

#[test]
fn lattice_lookups_never_disagree_with_direct_model() {
    check("surface lattice == StrategyModel", 20, |g| {
        let machine_name = *g.choose(&MACHINES);
        let dup = *g.choose(&[0.0, 0.25]);
        let surface = DecisionSurface::compile(machine_name, random_axes(g), dup)?;
        let (arch, params) = machines::parse(machine_name, 1).expect("registry machine");
        for &m in &surface.axes.msgs {
            for &d in &surface.axes.dest_nodes {
                for &gpn in &surface.axes.gpus_per_node {
                    let node = machines::with_shape(&arch, d + 1, gpn);
                    let sm = StrategyModel::new(&node, &params);
                    for &s in &surface.axes.sizes {
                        let ranked = surface.lookup(&Pattern {
                            n_msgs: m,
                            msg_size: s,
                            dest_nodes: d,
                            gpus_per_node: gpn,
                        });
                        let sc = Scenario { n_msgs: m, msg_size: s, n_dest: d, dup_frac: dup };
                        let inputs = sc.inputs(&node, node.cores_per_node());
                        let mut model_min = f64::INFINITY;
                        for (strategy, t_surface) in &ranked.ranked {
                            let t_model = sm.time(*strategy, &inputs);
                            if t_surface.to_bits() != t_model.to_bits() {
                                return Err(format!(
                                    "{machine_name} ({m} msgs x {s} B -> {d} nodes, {gpn} gpn): \
                                     surface {t_surface} != model {t_model} for {}",
                                    strategy.label()
                                ));
                            }
                            model_min = model_min.min(t_model);
                        }
                        if ranked.best().1.to_bits() != model_min.to_bits() {
                            return Err(format!(
                                "surface best {} != model minimum {model_min}",
                                ranked.best().1
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn off_lattice_lookups_stay_in_line_envelope() {
    check("interpolation bounded by its regime line", 30, |g| {
        let machine_name = *g.choose(&MACHINES);
        let surface = DecisionSurface::compile(machine_name, random_axes(g), 0.0)?;
        let axes = &surface.axes;
        // interior (possibly off-lattice) msgs/size; exact dest/gpn
        let q = Pattern {
            n_msgs: g.usize(axes.msgs[0], axes.msgs[axes.msgs.len() - 1] + 1),
            msg_size: g.usize(axes.sizes[0], axes.sizes[axes.sizes.len() - 1] + 1),
            dest_nodes: *g.choose(&axes.dest_nodes),
            gpus_per_node: *g.choose(&axes.gpus_per_node),
        };
        let ranked = surface.lookup(&q);
        for (strategy, t) in &ranked.ranked {
            if !t.is_finite() || *t <= 0.0 {
                return Err(format!("{}: non-positive time {t}", strategy.label()));
            }
            // envelope: lattice times of the same strategy on the same
            // (dest, gpn) line, over all msgs x sizes
            let mut lo = f64::INFINITY;
            let mut hi = 0f64;
            for &m in &axes.msgs {
                for &s in &axes.sizes {
                    let at = surface
                        .lookup(&Pattern { n_msgs: m, msg_size: s, ..q })
                        .time_of(*strategy)
                        .expect("strategy present on lattice");
                    lo = lo.min(at);
                    hi = hi.max(at);
                }
            }
            if *t < lo * (1.0 - 1e-9) || *t > hi * (1.0 + 1e-9) {
                return Err(format!("{}: {t} outside line envelope [{lo}, {hi}]", strategy.label()));
            }
        }
        Ok(())
    });
}

#[test]
fn batched_lookups_match_single_queries_bit_for_bit() {
    check("lookup_batch == lookup, bit for bit", 25, |g| {
        let machine_name = *g.choose(&MACHINES);
        let surface = DecisionSurface::compile(machine_name, random_axes(g), 0.0)?;
        let axes = &surface.axes;
        // below-lattice, interior (on- and off-lattice), and above-lattice
        // coordinates on every axis, so clamping, interpolation, and the
        // nearest-axis snaps all pass through the grouped path
        let n = g.usize(1, 48);
        let mut queries = Vec::with_capacity(n);
        for _ in 0..n {
            queries.push(Pattern {
                n_msgs: g.usize(axes.msgs[0] / 2 + 1, axes.msgs[axes.msgs.len() - 1] * 2),
                msg_size: g.usize(axes.sizes[0] / 2 + 1, axes.sizes[axes.sizes.len() - 1] * 2),
                dest_nodes: g.usize(1, 24),
                gpus_per_node: g.usize(1, 12),
            });
        }
        let batched = surface.lookup_batch(&queries);
        if batched.len() != queries.len() {
            return Err(format!("{} answers for {} queries", batched.len(), queries.len()));
        }
        for (q, got) in queries.iter().zip(&batched) {
            let want = surface.lookup(q);
            if got.ranked.len() != want.ranked.len() {
                return Err(format!("{machine_name} {q:?}: ranking lengths differ"));
            }
            for ((gs, gt), (ws, wt)) in got.ranked.iter().zip(&want.ranked) {
                if gs != ws || gt.to_bits() != wt.to_bits() {
                    return Err(format!(
                        "{machine_name} {q:?}: batched ({}, {gt}) != single ({}, {wt})",
                        gs.label(),
                        ws.label()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn quantized_artifacts_roundtrip_and_interchange_with_v2() {
    check("surface.v3 round-trips losslessly", 20, |g| {
        let machine_name = *g.choose(&MACHINES);
        let dup = *g.choose(&[0.0, 0.25]);
        let surface = DecisionSurface::compile(machine_name, random_axes(g), dup)?;
        let quant = persist::to_json_quant(&surface)?;
        let decoded = persist::parse_json(&quant)?;
        if decoded != surface {
            return Err(format!("{machine_name}: v3 round-trip changed the surface"));
        }
        // cross-format interchange: a surface that went through v3 writes
        // the same v2 bytes as one that never left memory
        if persist::to_json(&decoded) != persist::to_json(&surface) {
            return Err(format!("{machine_name}: v2 bytes drifted after a v3 round-trip"));
        }
        // and the v3 writer itself is byte-deterministic
        if persist::to_json_quant(&decoded)? != quant {
            return Err(format!("{machine_name}: v3 bytes drifted after a round-trip"));
        }
        Ok(())
    });
}
