//! Property tests for the resource-graph shape layer: NIC-rail assignment
//! is a deterministic, order-invariant function of the machine and the
//! message endpoints, and the legacy single-rail shape reproduces the
//! pre-shape-layer pipeline bit for bit (the golden oracle retained
//! through the refactor: identical builder output, the historical dense
//! NIC layout, and compiled == reference executor bits on every shape).

use hetcomm::comm::{build_schedule, Loc, Strategy};
use hetcomm::params::lassen_params;
use hetcomm::pattern::generators::random_pattern;
use hetcomm::pattern::CommPattern;
use hetcomm::sim::compiled::NO_NIC;
use hetcomm::sim::{run_reference, CompiledSchedule, Scratch};
use hetcomm::topology::machines::{frontier_4nic, frontier_like, lassen};
use hetcomm::topology::{Machine, NodeShape};
use hetcomm::util::prop::{check, Gen};
use hetcomm::util::rng::Rng;

/// A random machine with a random (possibly multi-rail) shape.
fn shaped_machine(g: &mut Gen) -> Machine {
    let mut m = match g.usize(0, 3) {
        0 => lassen(g.usize(2, 6)),
        1 => frontier_like(g.usize(2, 5)),
        _ => frontier_4nic(g.usize(2, 5)),
    };
    if g.bool(0.6) {
        let nics = g.usize(1, 5);
        m.shape = NodeShape::spread(m.sockets_per_node, nics, m.gpus_per_node());
    }
    m.shape.validate(m.sockets_per_node, m.gpus_per_node()).expect("generated shape is valid");
    m
}

/// The (src, dst, bytes, rail id, occupancy bits) of every lowered transfer
/// of a schedule — the observable rail assignment.
fn rail_tags(machine: &Machine, strategy: Strategy, pattern: &CommPattern) -> Vec<(Loc, Loc, usize, u32, u64)> {
    let params = lassen_params().compile();
    let schedule = build_schedule(strategy, machine, pattern);
    let cs = CompiledSchedule::lower(machine, &params, &schedule, strategy.sim_ppn(machine));
    let mut out = Vec::new();
    let mut i = 0usize;
    for phase in &schedule.phases {
        for x in &phase.xfers {
            if x.bytes == 0 {
                continue;
            }
            out.push((x.src, x.dst, x.bytes, cs.x_nic[i], cs.x_nic_busy[i].to_bits()));
            i += 1;
        }
    }
    assert_eq!(i, cs.x_nic.len(), "lowered transfer count mismatch");
    out
}

#[test]
fn rail_assignment_is_deterministic_and_order_invariant() {
    check("rails are a pure function of (machine, src, dst)", 40, |g| {
        let machine = shaped_machine(g);
        let mut rng = Rng::new(g.u64(1 << 40));
        let pattern = random_pattern(&machine, &mut rng, g.usize(16, 96), 1 << g.usize(6, 16), 0.2);
        for strategy in Strategy::all() {
            // same pattern twice: identical bits
            let a = rail_tags(&machine, strategy, &pattern);
            let b = rail_tags(&machine, strategy, &pattern);
            if a != b {
                return Err(format!("{}: lowering is not deterministic", strategy.label()));
            }
            // shuffled pattern: every (src, dst, bytes) keeps its rail.
            // (Multisets: the builders may reorder transfers, but no
            // message's rail may depend on its position in the pattern.)
            let mut shuffled = pattern.clone();
            let mut srng = Rng::new(g.u64(1 << 40) | 1);
            srng.shuffle(&mut shuffled.msgs);
            let mut a_sorted = a.clone();
            let mut c = rail_tags(&machine, strategy, &shuffled);
            a_sorted.sort();
            c.sort();
            if a_sorted != c {
                return Err(format!("{}: rail assignment moved under a pattern shuffle", strategy.label()));
            }
        }
        Ok(())
    });
}

#[test]
fn rails_stay_in_range_of_the_nic_block() {
    check("rail ids live inside the shape's NIC block", 40, |g| {
        let machine = shaped_machine(g);
        let rails = machine.nics_per_node();
        let params = lassen_params().compile();
        let mut rng = Rng::new(g.u64(1 << 40));
        let pattern = random_pattern(&machine, &mut rng, 64, 1 << 12, 0.1);
        for strategy in Strategy::all() {
            let ppn = strategy.sim_ppn(&machine);
            let schedule = build_schedule(strategy, &machine, &pattern);
            let cs = CompiledSchedule::lower(&machine, &params, &schedule, ppn);
            // the NIC block sits between the GPU block and the copy block
            let nic_base = machine.num_nodes * ppn + machine.total_gpus();
            for (&nic, &busy) in cs.x_nic.iter().zip(&cs.x_nic_busy) {
                if nic == NO_NIC {
                    if busy != 0.0 {
                        return Err("on-node transfer charged a NIC".into());
                    }
                    continue;
                }
                let slot = nic as usize - nic_base;
                if slot >= machine.num_nodes * rails {
                    return Err(format!(
                        "{}: rail slot {slot} outside the {}x{rails} NIC block",
                        strategy.label(),
                        machine.num_nodes
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn single_rail_shapes_reproduce_the_legacy_pipeline_bit_for_bit() {
    // The golden oracle: a machine whose shape is explicitly the legacy
    // single-rail node must build the same schedules, lower to the same
    // dense ids (one NIC timeline per node, occupancy = bytes / R_N), and
    // therefore simulate to the same bits as the preset default.
    check("1-NIC shape == pre-refactor builders and layout", 30, |g| {
        let default_machine = lassen(g.usize(2, 6));
        let mut legacy = default_machine.clone();
        legacy.shape = NodeShape::single_rail(legacy.sockets_per_node, legacy.gpus_per_node());
        if default_machine != legacy {
            return Err("presets must default to the single-rail shape".into());
        }

        let params = lassen_params();
        let compiled = params.compile();
        let mut rng = Rng::new(g.u64(1 << 40));
        let pattern = random_pattern(&default_machine, &mut rng, g.usize(16, 96), 1 << g.usize(6, 18), 0.25);
        for strategy in Strategy::all() {
            let ppn = strategy.sim_ppn(&default_machine);
            let a = build_schedule(strategy, &default_machine, &pattern);
            let b = build_schedule(strategy, &legacy, &pattern);
            if a != b {
                return Err(format!("{}: builder output moved under the shape layer", strategy.label()));
            }
            let cs = CompiledSchedule::lower(&legacy, &compiled, &a, ppn);
            let nic_base = legacy.num_nodes * ppn + legacy.total_gpus();
            let mut i = 0usize;
            for phase in &a.phases {
                for x in &phase.xfers {
                    if x.bytes == 0 {
                        continue;
                    }
                    if cs.x_nic[i] != NO_NIC {
                        // the historical dense layout: nic id == base + node
                        if cs.x_nic[i] as usize != nic_base + cs.x_node[i] as usize {
                            return Err(format!("{}: NIC id left the per-node layout", strategy.label()));
                        }
                        // and the historical occupancy: bytes / R_N exactly
                        let legacy_busy = (x.bytes as f64 * params.inv_rn).to_bits();
                        if cs.x_nic_busy[i].to_bits() != legacy_busy {
                            return Err(format!("{}: NIC occupancy moved a bit", strategy.label()));
                        }
                    }
                    i += 1;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn compiled_matches_reference_on_multi_rail_shapes() {
    // the equivalence oracle extended over the shape axis: both executors
    // learned about rails and must agree on every bit
    check("compiled == reference with rails", 30, |g| {
        let machine = shaped_machine(g);
        let params = lassen_params();
        let compiled = params.compile();
        let mut rng = Rng::new(g.u64(1 << 40));
        let pattern = random_pattern(&machine, &mut rng, g.usize(16, 80), 1 << g.usize(6, 18), 0.2);
        let mut scratch = Scratch::new();
        for strategy in Strategy::all() {
            let ppn = strategy.sim_ppn(&machine);
            let schedule = build_schedule(strategy, &machine, &pattern);
            let fast = scratch.run_totals(&machine, &compiled, &schedule, ppn);
            let slow = run_reference(&machine, &params, &schedule, ppn);
            if fast.total.to_bits() != slow.total.to_bits()
                || fast.max_node_injected != slow.max_node_injected
                || fast.internode_msgs != slow.internode_msgs
            {
                return Err(format!("{}: executors diverged on a shaped machine", strategy.label()));
            }
        }
        Ok(())
    });
}

#[test]
fn more_rails_never_slow_the_simulator() {
    // Monotonicity of the resource graph along the refinement chain
    // 1 -> 2 -> 4 rails on a Lassen-like node: each step splits every
    // rail's traffic, so NIC contention only relaxes (endpoint
    // serialization is untouched).
    check("rails monotone under refinement", 20, |g| {
        let base = lassen(g.usize(2, 5));
        let params = lassen_params().compile();
        let mut rng = Rng::new(g.u64(1 << 40));
        let pattern = random_pattern(&base, &mut rng, 64, 1 << 16, 0.2);
        let mut scratch = Scratch::new();
        for strategy in Strategy::all() {
            let ppn = strategy.sim_ppn(&base);
            let mut last = f64::INFINITY;
            for nics in [1usize, 2, 4] {
                let mut m = base.clone();
                m.shape = NodeShape::spread(m.sockets_per_node, nics, m.gpus_per_node());
                let schedule = build_schedule(strategy, &m, &pattern);
                let t = scratch.run_total(&m, &params, &schedule, ppn);
                if t > last * (1.0 + 1e-12) {
                    return Err(format!("{}: {nics} rails slower ({t} > {last})", strategy.label()));
                }
                last = t;
            }
        }
        Ok(())
    });
}
