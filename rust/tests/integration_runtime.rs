//! Integration: the PJRT runtime path — load the AOT JAX/Pallas artifacts
//! (HLO text), execute through the CPU PJRT client and compare against the
//! in-Rust ELL kernel and the CSR oracle.
//!
//! These tests require `make artifacts`; they are skipped (with a message)
//! when the artifacts are absent so `cargo test` works on a fresh clone.

use hetcomm::comm::{Strategy, StrategyKind, Transport};
use hetcomm::coordinator::{DistSpmv, SpmvConfig};
use hetcomm::runtime::{fitting_spec, spmv_specs, Runtime};
use hetcomm::sparse::gen;
use hetcomm::topology::machines::lassen;
use hetcomm::util::rng::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let rt = match Runtime::new(artifacts_dir()) {
        Ok(rt) => rt,
        Err(_) => return false,
    };
    rt.artifacts_present(&spmv_specs())
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("SKIP: artifacts missing — run `make artifacts`");
            return;
        }
    };
}

#[test]
fn pjrt_client_boots() {
    let rt = Runtime::new(artifacts_dir()).expect("PJRT CPU client");
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
}

#[test]
fn artifact_executes_and_matches_rust_kernel() {
    require_artifacts!();
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let spec = fitting_spec(256, 32, 16, 256).unwrap();
    let exe = rt.load(&spec).unwrap();

    // Random padded ELL problem at the artifact shape.
    let mut rng = Rng::new(7);
    let rows = spec.rows;
    let (dw, ow, ghost) = (spec.diag_width, spec.offd_width, spec.ghost);
    let mut diag_vals = vec![0f32; rows * dw];
    let mut diag_cols = vec![0i32; rows * dw];
    let mut offd_vals = vec![0f32; rows * ow];
    let mut offd_cols = vec![0i32; rows * ow];
    for i in 0..rows * dw {
        if rng.bool(0.4) {
            diag_vals[i] = rng.f64_in(-1.0, 1.0) as f32;
            diag_cols[i] = rng.usize_in(0, rows) as i32;
        }
    }
    for i in 0..rows * ow {
        if rng.bool(0.3) {
            offd_vals[i] = rng.f64_in(-1.0, 1.0) as f32;
            offd_cols[i] = rng.usize_in(0, ghost) as i32;
        }
    }
    let v_local: Vec<f32> = (0..rows).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect();
    let v_ghost: Vec<f32> = (0..ghost).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect();

    let got = exe.run_spmv(&diag_vals, &diag_cols, &offd_vals, &offd_cols, &v_local, &v_ghost).unwrap();

    // Reference: in-Rust ELL arithmetic.
    let mut want = vec![0f32; rows];
    for r in 0..rows {
        let mut acc = 0f32;
        for k in 0..dw {
            acc += diag_vals[r * dw + k] * v_local[diag_cols[r * dw + k] as usize];
        }
        for k in 0..ow {
            acc += offd_vals[r * ow + k] * v_ghost[offd_cols[r * ow + k] as usize];
        }
        want[r] = acc;
    }
    assert_eq!(got.len(), rows);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "row {i}: {g} vs {w}");
    }
}

#[test]
fn distributed_spmv_through_pjrt_verifies() {
    require_artifacts!();
    // 8x8x16 -> 1024 rows over 8 GPUs = 128 rows (two z-layers) per part:
    // slab thickness 2 keeps the offd ELL width <= 9 (single remote face),
    // within the artifact's static width of 16.
    let a = gen::stencil_27pt(8, 8, 16);
    let machine = lassen(2);
    let mut rng = Rng::new(11);
    let v: Vec<f32> = (0..a.nrows).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect();
    let cfg = SpmvConfig { use_pjrt: true, artifacts_dir: artifacts_dir(), ..Default::default() };
    for kind in [StrategyKind::Standard, StrategyKind::ThreeStep, StrategyKind::SplitMd] {
        let s = Strategy::new(kind, Transport::Staged).unwrap();
        let d = DistSpmv::new(&a, 8, &machine, s, cfg.clone()).unwrap();
        let rep = d.run(&v, 1).unwrap();
        assert_eq!(rep.verified, Some(true), "{}: max err {}", s.label(), rep.max_abs_err);
    }
}

#[test]
fn pjrt_power_iteration_e2e() {
    require_artifacts!();
    let a = gen::stencil_27pt(4, 4, 16); // 2-layer slabs per part (see above)
    let machine = lassen(2);
    let cfg = SpmvConfig { use_pjrt: true, artifacts_dir: artifacts_dir(), ..Default::default() };
    let s = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();
    let d = DistSpmv::new(&a, 8, &machine, s, cfg).unwrap();
    let (v, lambda, _, _) = d.power_iterate(&vec![1f32; a.nrows], 15).unwrap();
    // 27-pt stencil dominant eigenvalue is < 52 and > 26 on a small cube.
    assert!(lambda > 10.0 && lambda < 52.0, "lambda {lambda}");
    let av = a.spmv(&v);
    let mut resid = 0f32;
    for (x, y) in av.iter().zip(&v) {
        resid = resid.max((x - lambda * y).abs());
    }
    assert!(resid / lambda < 0.2, "relative residual {}", resid / lambda);
}

#[test]
fn persistent_engine_through_pjrt() {
    require_artifacts!();
    use hetcomm::coordinator::{Engine, EngineConfig};
    let a = gen::stencil_27pt(8, 8, 16);
    let machine = lassen(2);
    let mut rng = Rng::new(19);
    let v: Vec<f32> = (0..a.nrows).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect();
    let s = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();
    let cfg = EngineConfig { use_pjrt: true, artifacts_dir: artifacts_dir(), ..Default::default() };
    let mut eng = Engine::new(&a, 8, &machine, s, &v, cfg).unwrap();
    let expect = a.spmv(&v);
    for _ in 0..3 {
        let w = eng.iterate(None).unwrap();
        let scale = expect.iter().fold(1f32, |m, x| m.max(x.abs()));
        for (i, (x, y)) in expect.iter().zip(&w).enumerate() {
            assert!((x - y).abs() <= 1e-4 * scale, "row {i}: {x} vs {y}");
        }
    }
    let stats = eng.shutdown();
    assert_eq!(stats.iterations, 3);
}

#[test]
fn engine_pjrt_overlap_matches_fused() {
    require_artifacts!();
    use hetcomm::coordinator::{Engine, EngineConfig};
    let a = gen::stencil_27pt(4, 4, 16);
    let machine = lassen(2);
    let v: Vec<f32> = (0..a.nrows).map(|i| ((i * 7 % 13) as f32 - 6.0) / 6.0).collect();
    let s = Strategy::new(StrategyKind::ThreeStep, Transport::Staged).unwrap();
    let mk = |overlap| EngineConfig { use_pjrt: true, artifacts_dir: artifacts_dir(), overlap, ..Default::default() };
    let mut e1 = Engine::new(&a, 8, &machine, s, &v, mk(true)).unwrap();
    let mut e2 = Engine::new(&a, 8, &machine, s, &v, mk(false)).unwrap();
    let w1 = e1.iterate(None).unwrap();
    let w2 = e2.iterate(None).unwrap();
    for (a, b) in w1.iter().zip(&w2) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn missing_artifact_is_clean_error() {
    let rt = Runtime::new("/nonexistent-artifacts").unwrap();
    let spec = fitting_spec(256, 32, 16, 256).unwrap();
    let err = match rt.load(&spec) {
        Ok(_) => panic!("load from /nonexistent-artifacts unexpectedly succeeded"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("parsing HLO text"), "{err:#}");
}

#[test]
fn shape_mismatch_rejected() {
    require_artifacts!();
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let spec = fitting_spec(256, 32, 16, 256).unwrap();
    let exe = rt.load(&spec).unwrap();
    // wrong v_local length
    let err = exe.run_spmv(
        &vec![0f32; 256 * 32],
        &vec![0i32; 256 * 32],
        &vec![0f32; 256 * 16],
        &vec![0i32; 256 * 16],
        &vec![0f32; 100],
        &vec![0f32; 256],
    );
    assert!(err.is_err());
}
