//! Integration tests for the resource-graph shape layer — the paper's §6
//! claims made executable: node shape (NIC count, injection bandwidth,
//! GPU↔NIC affinity) moves the strategy crossover points, and in
//! particular node-aware *host staging* keeps winning to larger message
//! sizes as injection rails are added.

use hetcomm::advisor::{persist, DecisionSurface, SurfaceAxes};
use hetcomm::comm::{StrategyKind, Transport};
use hetcomm::model::StrategyModel;
use hetcomm::pattern::generators::Scenario;
use hetcomm::sweep::{run_sweep, GridSpec, PatternGen, SweepConfig};
use hetcomm::topology::machines;
use hetcomm::topology::NodeShape;

/// Best staged node-aware time vs best of everything else (device-aware
/// node-aware and both standard flavors) at one scenario point.
fn staged_na_wins(sm: &StrategyModel, inputs: &hetcomm::model::ModelInputs) -> bool {
    let mut staged_na = f64::INFINITY;
    let mut other = f64::INFINITY;
    for (s, t) in sm.all_times(inputs) {
        if s.transport == Transport::Staged && s.kind != StrategyKind::Standard {
            staged_na = staged_na.min(t);
        } else {
            other = other.min(t);
        }
    }
    staged_na < other
}

#[test]
fn frontier_rails_widen_the_staged_node_aware_regime() {
    // The §6 prediction on the Frontier-like node (4 Slingshot rails at
    // per-rail EDR-class bandwidth): with one rail the staged node-aware
    // regime ends below 12 KiB; two rails carry it past 12 KiB; four rails
    // past 24 KiB; nobody holds 32 KiB. (Python transcription: the exact
    // regime boundary is ~9.3 KB / ~16.7 KB / ~27.4 KB for 1 / 2 / 4
    // rails; every probe below clears its verdict by >= 3%.)
    let (_, params) = machines::parse("frontier-4nic", 17).unwrap();
    let expected: [(usize, [bool; 3]); 4] = [
        (8192, [true, true, true]),
        (12288, [false, true, true]),
        (24576, [false, false, true]),
        (32768, [false, false, false]),
    ];
    for (size, wins) in expected {
        for (k, &nics) in [1usize, 2, 4].iter().enumerate() {
            let mut machine = machines::frontier_like(17);
            machine.shape = NodeShape::spread(1, nics, 4);
            let sm = StrategyModel::new(&machine, &params);
            let sc = Scenario { n_msgs: 256, msg_size: size, n_dest: 16, dup_frac: 0.0 };
            let inputs = sc.inputs(&machine, machine.cores_per_node());
            assert_eq!(inputs.nics, nics, "shape must reach the model inputs");
            assert_eq!(
                staged_na_wins(&sm, &inputs),
                wins[k],
                "{size} B on {nics} rails: staged node-aware verdict moved"
            );
        }
    }
}

#[test]
fn sweep_winner_regime_widens_with_rails() {
    // The same §6 effect through the full sweep pipeline on a Lassen-like
    // node: along the 256-msgs -> 16-nodes line, the 4 KiB lattice cell is
    // won by device-aware 3-Step at one rail and flips to *staged* 3-Step
    // at four rails (>= 11% margins in the Python transcription), so the
    // largest staged-node-aware winning size strictly grows.
    let cfg = SweepConfig {
        grid: GridSpec {
            gens: vec![PatternGen::Uniform],
            dest_nodes: vec![16],
            gpus_per_node: vec![4],
            nics: vec![1, 4],
            sizes: (4..=20).step_by(2).map(|e| 1usize << e).collect(),
            n_msgs: 256,
            dup_frac: 0.0,
        },
        sim: false,
        threads: 2,
        ..Default::default()
    };
    let r = run_sweep(&cfg).unwrap();
    let widest_staged_na = |nics: usize| -> usize {
        r.report
            .winners
            .iter()
            .filter(|w| w.nics == nics && w.winner_staged && w.winner_kind != StrategyKind::Standard)
            .map(|w| w.size)
            .max()
            .unwrap_or(0)
    };
    let one = widest_staged_na(1);
    let four = widest_staged_na(4);
    assert!(one >= 1024, "staged node-aware must win the small sizes at one rail (got {one})");
    assert!(four > one, "4 rails must widen the staged node-aware regime ({four} !> {one})");
    // the flip cell itself
    let at = |nics: usize, size: usize| {
        r.report.winners.iter().find(|w| w.nics == nics && w.size == size).expect("lattice cell present")
    };
    let flip_1 = at(1, 4096);
    assert!(!flip_1.winner_staged, "4 KiB at one rail is device-aware territory, got {}", flip_1.winner);
    let flip_4 = at(4, 4096);
    assert!(
        flip_4.winner_staged && flip_4.winner_kind == StrategyKind::ThreeStep,
        "4 KiB at four rails must flip to staged 3-Step, got {}",
        flip_4.winner
    );
}

#[test]
fn shaped_surface_artifacts_deterministic_and_versioned() {
    let axes = SurfaceAxes {
        msgs: vec![64, 256],
        sizes: vec![1 << 8, 1 << 12, 1 << 16],
        dest_nodes: vec![4, 16],
        gpus_per_node: vec![4],
    };
    // two compiles of the pinned 4-NIC machine: byte-identical v2 artifacts
    let a = DecisionSurface::compile("frontier-4nic", axes.clone(), 0.0).unwrap();
    let b = DecisionSurface::compile("frontier-4nic", axes.clone(), 0.0).unwrap();
    let (ja, jb) = (persist::to_json(&a), persist::to_json(&b));
    assert_eq!(ja, jb, "shaped surface compile must be deterministic");
    assert!(ja.contains("\"schema\": \"hetcomm.surface.v2\""));
    assert!(ja.contains("\"nics\": 4"));
    assert_eq!(persist::parse_json(&ja).unwrap(), a);
    // the single-rail machine stays on v1 bytes with no shape key at all
    let legacy = DecisionSurface::compile("lassen", axes, 0.0).unwrap();
    let jl = persist::to_json(&legacy);
    assert!(jl.contains("\"schema\": \"hetcomm.surface.v1\""));
    assert!(!jl.contains("nics"));
    assert_eq!(persist::parse_json(&jl).unwrap().nics, 1);
}

#[test]
fn shaped_surface_lookup_prefers_staging_longer() {
    // shape-keyed serving: the 4-rail surface keeps recommending staged
    // node-aware strategies at sizes where the single-rail surface has
    // already switched to device-aware
    let axes = SurfaceAxes {
        msgs: vec![256],
        sizes: vec![1 << 10, 1 << 12, 1 << 14],
        dest_nodes: vec![16],
        gpus_per_node: vec![4],
    };
    let one = DecisionSurface::compile_shaped("lassen", 1, axes.clone(), 0.0).unwrap();
    let four = DecisionSurface::compile_shaped("lassen", 4, axes, 0.0).unwrap();
    let q = hetcomm::advisor::Pattern { n_msgs: 256, msg_size: 4096, dest_nodes: 16, gpus_per_node: 4 };
    let (w1, _) = one.lookup(&q).best();
    let (w4, _) = four.lookup(&q).best();
    assert_eq!((w1.transport, w1.kind), (Transport::DeviceAware, StrategyKind::ThreeStep));
    assert_eq!((w4.transport, w4.kind), (Transport::Staged, StrategyKind::ThreeStep));
}
