//! Integration: the online strategy advisor end to end — deterministic
//! compile + artifact round-trip, agreement with `hetcomm sweep`'s winners
//! and per-regime report on the Table 6 regimes for all three machines,
//! cached burst behavior, and the measurement-driven recalibration loop.

use hetcomm::advisor::{persist, AdvisorService, Calibrator, DecisionSurface, Pattern, SurfaceAxes};
use hetcomm::sweep::{run_sweep, GridSpec, PatternGen, SweepConfig, SMALL_BAND_MAX};
use hetcomm::topology::machines;

const MACHINES: [&str; 3] = ["lassen", "frontier-like", "delta-like"];
const SIZES: [usize; 5] = [16, 256, 1024, 4096, 1 << 18];

fn table6_axes() -> SurfaceAxes {
    SurfaceAxes { msgs: vec![256], sizes: SIZES.to_vec(), dest_nodes: vec![4, 16], gpus_per_node: vec![4] }
}

fn table6_sweep(machine: &str) -> SweepConfig {
    SweepConfig {
        grid: GridSpec {
            gens: vec![PatternGen::Uniform],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
            nics: vec![1],
            sizes: SIZES.to_vec(),
            n_msgs: 256,
            dup_frac: 0.0,
        },
        sim: false,
        machine: machine.into(),
        ..Default::default()
    }
}

#[test]
fn compile_is_deterministic_and_artifacts_roundtrip() {
    for machine in MACHINES {
        let a = DecisionSurface::compile(machine, table6_axes(), 0.0).unwrap();
        let b = DecisionSurface::compile(machine, table6_axes(), 0.0).unwrap();
        assert_eq!(persist::to_json(&a), persist::to_json(&b), "{machine}: artifact must be byte-stable");
        let parsed = persist::parse_json(&persist::to_json(&a)).unwrap();
        assert_eq!(a, parsed, "{machine}: artifact must round-trip bit-for-bit");
    }
}

#[test]
fn advisor_queries_match_sweep_winners_on_all_machines() {
    // Acceptance: `advise --query` answers the Table 6 regimes with the
    // same winner the sweep reports, per cell, for all three machines.
    for machine in MACHINES {
        let sweep = run_sweep(&table6_sweep(machine)).unwrap();
        let surface = DecisionSurface::compile(machine, table6_axes(), 0.0).unwrap();
        assert!(sweep.report.winners.len() >= 3, "need >= 3 regime cells to compare");
        for w in &sweep.report.winners {
            let query =
                Pattern { n_msgs: 256, msg_size: w.size, dest_nodes: w.dest_nodes, gpus_per_node: w.gpus_per_node };
            let (best, secs) = surface.lookup(&query).best();
            assert_eq!(
                best.label(),
                w.winner,
                "{machine}: advisor disagrees with sweep at {} B x {} nodes",
                w.size,
                w.dest_nodes
            );
            assert_eq!(secs.to_bits(), w.model_s.to_bits(), "{machine}: winning time must match the sweep's");
        }
    }
}

#[test]
fn advisor_totals_match_sweep_regime_report() {
    // The per-regime (band) report: totalling the advisor's per-size answers
    // over a band must select the same winner as the sweep's regime report.
    for machine in MACHINES {
        let sweep = run_sweep(&table6_sweep(machine)).unwrap();
        let surface = DecisionSurface::compile(machine, table6_axes(), 0.0).unwrap();
        let mut checked = 0;
        for regime in &sweep.report.regimes {
            let mut totals = vec![0.0f64; surface.strategies.len()];
            for &size in SIZES.iter().filter(|&&s| (s <= SMALL_BAND_MAX) == (regime.band == "small")) {
                let query =
                    Pattern { n_msgs: 256, msg_size: size, dest_nodes: regime.dest_nodes, gpus_per_node: 4 };
                let ranked = surface.lookup(&query);
                for (k, &strategy) in surface.strategies.iter().enumerate() {
                    totals[k] += ranked.time_of(strategy).expect("all strategies ranked");
                }
            }
            let mut best = 0;
            for (k, &t) in totals.iter().enumerate() {
                if t < totals[best] {
                    best = k;
                }
            }
            assert_eq!(
                surface.strategies[best].label(),
                regime.winner,
                "{machine}: {} nodes / {} band",
                regime.dest_nodes,
                regime.band
            );
            checked += 1;
        }
        assert!(checked >= 4, "expected >= 4 regimes, checked {checked}");
    }
}

#[test]
fn burst_is_deterministic_with_high_hit_rate() {
    let surface = DecisionSurface::compile("lassen", table6_axes(), 0.0).unwrap();
    let svc = AdvisorService::new(vec![surface.clone()]);
    let r1 = svc.bench_burst(20_000, 7, 4).unwrap();
    assert_eq!(r1.queries, 20_000);
    assert_eq!(r1.winners.values().sum::<usize>(), 20_000);
    assert!(r1.p99_s >= r1.p50_s && r1.p50_s >= 0.0);
    // same seed, different thread count: answers must be identical, and the
    // single-threaded run's miss count is exactly its distinct pool
    let r2 = AdvisorService::new(vec![surface]).bench_burst(20_000, 7, 1).unwrap();
    assert_eq!(r1.winners, r2.winners);
    assert_eq!(r1.distinct, r2.distinct);
    assert!(r2.cache.misses as usize <= r2.distinct, "misses {} > pool {}", r2.cache.misses, r2.distinct);
    assert!(r2.cache.hit_rate() > 0.9, "hit rate {}", r2.cache.hit_rate());
}

#[test]
fn recalibration_loop_updates_surface_and_cache() {
    let (_, base_params) = machines::parse("lassen", 2).unwrap();
    let surface = DecisionSurface::compile("lassen", table6_axes(), 0.0).unwrap();
    let baseline = surface.clone();
    let svc = AdvisorService::new(vec![surface]);
    let q = Pattern { n_msgs: 256, msg_size: 1024, dest_nodes: 16, gpus_per_node: 4 };
    let before = svc.advise_for("lassen", &q).unwrap();

    // "measured" timings: the eager off-node path runs 3x slower than the
    // table says; refit and apply
    let mut cal = Calibrator::new(base_params.clone());
    let truth = base_params.cpu_ab(hetcomm::Protocol::Eager, hetcomm::Locality::OffNode);
    for exp in 9..13 {
        let bytes = 1usize << exp;
        cal.ingest(bytes, 3.0 * truth.time(bytes));
    }
    let report = cal.refit().unwrap();
    let recompiled = svc.recalibrate("lassen", &report.params, report.stale_lo, report.stale_hi).unwrap();
    assert!(recompiled > 0, "the refit band covers lattice sizes 1024 and 4096");

    let after = svc.advise_for("lassen", &q).unwrap();
    assert_ne!(before.ranked, after.ranked, "recalibration must reach served answers");
    // sizes outside the refit band keep their original answers
    let untouched = Pattern { msg_size: 1 << 18, ..q };
    let got = svc.advise_for("lassen", &untouched).unwrap();
    assert_eq!(got.ranked, baseline.lookup(&untouched).ranked);
}

#[test]
fn mid_burst_recalibration_is_tenant_isolated_and_never_torn() {
    // Two tenants; tenant A ("lassen") is republished repeatedly while
    // reader threads hammer both. Tenant B's answers must never move, and
    // every tenant-A answer must match some single published epoch in full —
    // a mixed-epoch (torn) ranking matches none of them.
    fn bits(r: &hetcomm::advisor::RankedStrategies) -> Vec<(&'static str, u64)> {
        r.ranked.iter().map(|(s, t)| (s.label(), t.to_bits())).collect()
    }
    let base = DecisionSurface::compile("lassen", table6_axes(), 0.0).unwrap();
    let svc = AdvisorService::new(vec![
        base.clone(),
        DecisionSurface::compile("frontier-like", table6_axes(), 0.0).unwrap(),
    ]);
    // off-lattice queries so both the interpolator and the memo are in play
    let qa = Pattern { n_msgs: 200, msg_size: 700, dest_nodes: 16, gpus_per_node: 4 };
    let qb = Pattern { n_msgs: 200, msg_size: 2000, dest_nodes: 4, gpus_per_node: 4 };
    let control_b = bits(&DecisionSurface::compile("frontier-like", table6_axes(), 0.0).unwrap().lookup(&qb));

    // every ranking tenant A may legally serve: one per epoch. A full-band
    // republish recompiles every cell from that round's parameters alone, so
    // epoch r's surface is reproducible straight from the base surface.
    let (_, base_params) = machines::parse("lassen", 2).unwrap();
    let rounds = 6u64;
    let mut legal: Vec<Vec<(&'static str, u64)>> = vec![bits(&base.lookup(&qa))];
    for r in 1..=rounds {
        let params = base_params.scaled(1.0 + r as f64 * 0.5, 1.0);
        let (next, _) = base.recalibrated(&params, 1, 1 << 30).unwrap();
        legal.push(bits(&next.lookup(&qa)));
    }
    for w in legal.windows(2) {
        assert_ne!(w[0], w[1], "consecutive epochs must serve distinguishable answers");
    }

    std::thread::scope(|scope| {
        for _ in 0..2 {
            scope.spawn(|| {
                for _ in 0..400 {
                    let a = svc.advise_for("lassen", &qa).unwrap();
                    assert!(legal.contains(&bits(&a)), "tenant A served a torn or unknown ranking");
                    let b = svc.advise_for("frontier-like", &qb).unwrap();
                    assert_eq!(bits(&b), control_b, "tenant B's answers moved during A's recalibration");
                }
            });
        }
        for r in 1..=rounds {
            let params = base_params.scaled(1.0 + r as f64 * 0.5, 1.0);
            let recompiled = svc.recalibrate("lassen", &params, 1, 1 << 30).unwrap();
            assert_eq!(recompiled, table6_axes().len(), "a full-band refit recompiles every cell");
        }
    });

    assert_eq!(svc.snapshot(0).unwrap().epoch, rounds, "tenant A's epoch advances once per publish");
    assert_eq!(svc.snapshot(1).unwrap().epoch, 0, "tenant B was never republished");
    assert_eq!(bits(&svc.advise_for("lassen", &qa).unwrap()), legal[rounds as usize]);
    assert_eq!(bits(&svc.advise_for("frontier-like", &qb).unwrap()), control_b);
}
