//! Trace-format properties: arbitrary traces round-trip through the
//! `hetcomm.trace.v1` artifact bit for bit, serialization is byte-stable,
//! and the self-checking drift metadata rejects tampering.

use hetcomm::pattern::generators::random_pattern;
use hetcomm::topology::machines;
use hetcomm::trace::{persist, Epoch, Trace};
use hetcomm::util::prop::{check, Gen};
use hetcomm::FaultKind;

/// A random trace: a random registry machine shape holding 1–6 epochs of
/// random irregular patterns with adversarial tags; some epochs carry
/// fault events so the optional `"faults"` key is exercised too.
fn random_trace(g: &mut Gen) -> Trace {
    let name = *g.choose(&machines::NAMES);
    let (arch, _) = machines::parse(name, 1).expect("registry name");
    let nodes = g.usize(2, 6);
    let gpn = arch.sockets_per_node * g.usize(1, 4);
    let machine = machines::with_shape(&arch, nodes, gpn);
    let rails = machine.nics_per_node();
    let n_epochs = g.usize(1, 7);
    let epochs = (0..n_epochs)
        .map(|k| {
            let n_msgs = g.usize(1, 40);
            let max_bytes = g.msg_size().max(2);
            let dup_p = *g.choose(&[0.0, 0.3]);
            let pattern = random_pattern(&machine, g.rng(), n_msgs, max_bytes, dup_p);
            // tags exercise the JSON string escaper
            let tag = format!("e{k}\t\"quoted\\{}\"", g.usize(0, 100));
            let faults = match g.usize(0, 5) {
                0 => vec![FaultKind::RailDown { rail: g.usize(0, rails - 1) }],
                1 => vec![FaultKind::Slowdown { rail: g.usize(0, rails - 1), factor: 1.0 + g.usize(1, 6) as f64 * 0.5 }],
                2 => vec![FaultKind::Congestion { level: g.usize(1, 100) as f64 * 1e-6 }],
                _ => vec![],
            };
            Epoch { index: k, tag, repeat: g.usize(1, 5), pattern, faults }
        })
        .collect();
    Trace { scenario: format!("prop \"{}\"", g.usize(0, 1000)), seed: g.u64(u64::MAX), machine, epochs }
}

#[test]
fn traces_roundtrip_bit_for_bit() {
    check("trace emit -> parse is the identity", 60, |g| {
        let trace = random_trace(g);
        trace.validate()?;
        let json = persist::to_json(&trace);
        let parsed = persist::parse_json(&json).map_err(|e| format!("parse failed: {e}\n{json}"))?;
        if parsed != trace {
            return Err("parsed trace differs from the original".into());
        }
        // emit is byte-stable across the round trip
        let again = persist::to_json(&parsed);
        if again != json {
            return Err("re-emitted artifact bytes differ".into());
        }
        Ok(())
    });
}

#[test]
fn epoch_stats_and_drift_survive_the_roundtrip() {
    check("derived metadata is reconstruction-invariant", 30, |g| {
        let trace = random_trace(g);
        let parsed = persist::parse_json(&persist::to_json(&trace)).map_err(|e| e.to_string())?;
        if parsed.epoch_stats() != trace.epoch_stats() {
            return Err("epoch stats changed across the round trip".into());
        }
        let (a, b) = (trace.drifts(), parsed.drifts());
        if a.len() != b.len() || a.iter().zip(&b).any(|(x, y)| x.to_bits() != y.to_bits()) {
            return Err(format!("drifts changed: {a:?} vs {b:?}"));
        }
        Ok(())
    });
}

#[test]
fn custom_shapes_roundtrip_faithfully() {
    // a machine whose GPU↔NIC affinity departs from the canonical spread
    // layout must persist its full resource graph, not just a rail count
    check("non-canonical NodeShape survives the artifact", 20, |g| {
        let mut trace = random_trace(g);
        let gpn = trace.machine.gpus_per_node();
        let sockets = trace.machine.sockets_per_node;
        // 2 rails per socket with every GPU pinned to rail 1 — spread would
        // start the affinity map at rail 0, so this is never canonical
        trace.machine.shape =
            hetcomm::topology::NodeShape { nics_per_socket: vec![2; sockets], gpu_nic: vec![1; gpn] };
        let json = persist::to_json(&trace);
        if !json.contains("nics_per_socket") {
            return Err("custom shape must serialize its full resource graph".into());
        }
        let parsed = persist::parse_json(&json).map_err(|e| format!("parse failed: {e}"))?;
        if parsed.machine.shape != trace.machine.shape {
            return Err("custom shape changed across the round trip".into());
        }
        if persist::to_json(&parsed) != json {
            return Err("re-emitted custom-shape artifact bytes differ".into());
        }
        Ok(())
    });
}

#[test]
fn degraded_shapes_roundtrip_faithfully() {
    // a post-rail-failure shape (dense renumbering of the survivors plus a
    // remapped affinity table) is non-canonical, so it must persist via the
    // full nics_per_socket/gpu_nic arrays and reload bit-for-bit
    check("degraded NodeShape survives the artifact", 20, |g| {
        let (arch, _) = machines::parse("frontier-4nic", 1).expect("registry name");
        let nodes = g.usize(2, 5);
        let mut machine = machines::with_shape(&arch, nodes, arch.gpus_per_node());
        let rails = machine.nics_per_node();
        // downing the last rail of the spread layout happens to re-spread
        // canonically; any other rail leaves a non-canonical affinity map
        let down = g.usize(0, rails - 2);
        machine.shape = machine.shape.degraded(&[down]).map_err(|e| e.to_string())?;
        let n_epochs = g.usize(1, 4);
        let epochs: Vec<Epoch> = (0..n_epochs)
            .map(|k| {
                let pattern = random_pattern(&machine, g.rng(), g.usize(1, 30), g.msg_size().max(2), 0.0);
                Epoch { index: k, tag: format!("deg{k}"), repeat: g.usize(1, 3), pattern, faults: vec![] }
            })
            .collect();
        let trace = Trace { scenario: "degraded".into(), seed: g.u64(u64::MAX), machine, epochs };
        trace.validate()?;
        let json = persist::to_json(&trace);
        if !json.contains("nics_per_socket") {
            return Err("degraded shape must serialize its full resource graph".into());
        }
        let parsed = persist::parse_json(&json).map_err(|e| format!("parse failed: {e}"))?;
        if parsed.machine.shape != trace.machine.shape {
            return Err("degraded shape changed across the round trip".into());
        }
        if persist::to_json(&parsed) != json {
            return Err("re-emitted degraded-shape artifact bytes differ".into());
        }
        Ok(())
    });
}

#[test]
fn tampered_stats_metadata_is_rejected() {
    check("metadata self-check catches stats tampering", 20, |g| {
        let trace = random_trace(g);
        let json = persist::to_json(&trace);
        // bump the declared inter-node message count of epoch 0 without
        // touching the message list: the parser must refuse the artifact
        let n = trace.epoch_stats()[0].total_internode_msgs;
        let needle = format!("\"stats\": {{\"msgs\": {n},");
        let tampered = json.replacen(&needle, &format!("\"stats\": {{\"msgs\": {},", n + 1), 1);
        if tampered == json {
            return Err(format!("needle {needle:?} not found in the artifact"));
        }
        match persist::parse_json(&tampered) {
            Err(e) if e.contains("disagree") => Ok(()),
            Err(e) => Err(format!("wrong rejection: {e}")),
            Ok(_) => Err("tampered stats metadata must be rejected".into()),
        }
    });
}
