//! Fault-injection integration: a mid-trace rail failure on a stationary
//! workload is invisible to pattern drift, so only the external-drift
//! residual can trigger re-advice — the adaptive policy must switch within
//! a bounded number of epochs of the failure and beat every static
//! strategy by a real margin. The test self-calibrates: it searches the
//! model for an operating point where the healthy and degraded winners
//! genuinely differ instead of hard-coding one.
//!
//! The flip side is the zero-fault safety rail: with no schedule (or an
//! all-identity one) the fault-aware entry point must reproduce the
//! legacy replay byte for byte.

use hetcomm::comm::Strategy;
use hetcomm::fault::{FaultEvent, FaultKind, FaultSpec, FaultState};
use hetcomm::model::{ModelInputs, StrategyModel};
use hetcomm::pattern::generators::Scenario;
use hetcomm::pattern::CommPattern;
use hetcomm::topology::{machines, Machine};
use hetcomm::trace::replay::{render_report, replay, replay_with_faults, report_to_json, ReplayConfig, ReplayMode};
use hetcomm::trace::{synthesize, Epoch, Trace, TraceScenario, DEFAULT_DRIFT_THRESHOLD};

const NODES: usize = 9;
const EPOCHS: usize = 6;
const FAULT_EPOCH: usize = 3;
const REPEAT: usize = 2;
/// Required relative margin of the piecewise-optimal policy over every
/// static strategy at the calibrated operating point.
const MARGIN: f64 = 0.01;

/// Model inputs exactly as replay assembles them: stats on the healthy
/// machine (rail loss moves no GPUs), rail count from the system in force.
fn inputs_for(pattern: &CommPattern, healthy: &Machine, in_force: &Machine) -> ModelInputs {
    let stats = pattern.stats(healthy);
    ModelInputs {
        s_proc: stats.s_proc,
        s_node: stats.s_node,
        s_n2n: stats.s_n2n,
        m_p2n: stats.m_p2n,
        m_n2n: stats.m_n2n,
        m_std: stats.m_std,
        ppn: healthy.cores_per_node(),
        nics: in_force.nics_per_node(),
        dup_frac: pattern.duplicate_fraction(healthy),
    }
}

/// Search the (size × msgs × dest) space for an operating point where the
/// rail failure flips the model winner with at least `MARGIN` to spare
/// against every static strategy, and return the winning pattern.
fn calibrate() -> (CommPattern, Strategy, Strategy) {
    let (machine, params) = machines::parse("frontier-4nic", NODES).expect("registry machine");
    let mut st = FaultState::default();
    st.apply(&FaultKind::RailDown { rail: 3 });
    let (dm, dp) = st.degrade(&machine, &params).expect("one of four rails down is survivable");
    let healthy_model = StrategyModel::new(&machine, &params);
    let degraded_model = StrategyModel::new(&dm, &dp);

    let n_pre = (FAULT_EPOCH * REPEAT) as f64;
    let n_post = ((EPOCHS - FAULT_EPOCH) * REPEAT) as f64;
    let mut found: Option<(f64, CommPattern, Strategy, Strategy)> = None;
    for exp in 4..=20 {
        for n_msgs in [64usize, 256, 512] {
            for n_dest in [4usize, 8] {
                let sc = Scenario { n_msgs, msg_size: 1usize << exp, n_dest, dup_frac: 0.0 };
                let pattern = sc.materialize(&machine);
                let h_times = healthy_model.all_times(&inputs_for(&pattern, &machine, &machine));
                let d_times = degraded_model.all_times(&inputs_for(&pattern, &machine, &dm));
                let argmin = |ts: &[(Strategy, f64)]| {
                    ts.iter().skip(1).fold(ts[0], |acc, &c| if c.1 < acc.1 { c } else { acc })
                };
                let (a, a_h) = argmin(&h_times);
                let (b, b_d) = argmin(&d_times);
                if a == b {
                    continue;
                }
                let adaptive = n_pre * a_h + n_post * b_d;
                let margin = h_times
                    .iter()
                    .zip(&d_times)
                    .map(|(&(_, sh), &(_, sd))| {
                        let total = n_pre * sh + n_post * sd;
                        (total - adaptive) / total
                    })
                    .fold(f64::INFINITY, f64::min);
                if margin > found.as_ref().map(|f| f.0).unwrap_or(MARGIN) {
                    found = Some((margin, pattern, a, b));
                }
            }
        }
    }
    let (margin, pattern, a, b) = found.expect(
        "no operating point flips the model winner when a frontier-4nic rail fails — \
         the rail count no longer reaches the Table 6 models",
    );
    assert!(margin >= MARGIN);
    (pattern, a, b)
}

fn stationary_trace(pattern: &CommPattern) -> Trace {
    let (machine, _) = machines::parse("frontier-4nic", NODES).expect("registry machine");
    let epochs = (0..EPOCHS)
        .map(|k| Epoch { index: k, tag: "steady".into(), repeat: REPEAT, pattern: pattern.clone(), faults: vec![] })
        .collect();
    Trace { scenario: "stationary-fault".into(), seed: 23, machine, epochs }
}

/// The schedule under test: a rail fails mid-trace, with background
/// congestion so the observation stream unmistakably leaves the belief
/// model's prediction band. Congestion never enters the closed-form
/// models, so the calibrated winner flip is untouched.
fn schedule() -> FaultSpec {
    FaultSpec {
        seed: 31,
        events: vec![
            FaultEvent { epoch: FAULT_EPOCH, kind: FaultKind::RailDown { rail: 3 } },
            FaultEvent { epoch: FAULT_EPOCH, kind: FaultKind::Congestion { level: 5e-3 } },
        ],
    }
}

#[test]
fn rail_failure_recovery_beats_every_static_within_bounded_epochs() {
    let (pattern, pre_winner, post_winner) = calibrate();
    let trace = stationary_trace(&pattern);
    let spec = schedule();
    let mode = ReplayMode::Adaptive { surface: None };
    let report = replay_with_faults(&trace, &mode, &ReplayConfig::default(), Some(&spec)).unwrap();

    // the workload is stationary: pattern drift never fires, so any switch
    // is the external-drift residual's doing
    assert!(report.rows.iter().all(|r| r.drift == 0.0), "stationary trace must show zero pattern drift");
    assert_eq!(report.rows[FAULT_EPOCH].fault.as_deref(), Some("rail-down(3), congestion(0.005)"));
    let residual = report.rows[FAULT_EPOCH].residual.expect("incumbent residual at the fault epoch");
    assert!(residual > DEFAULT_DRIFT_THRESHOLD, "residual {residual} must cross the trigger threshold");

    // bounded recovery: the policy held the healthy winner, then switched
    // to the degraded winner at the fault epoch itself
    for row in &report.rows[..FAULT_EPOCH] {
        assert_eq!(row.strategy, pre_winner, "pre-fault epochs run the healthy winner");
    }
    assert_eq!(report.rows[FAULT_EPOCH].strategy, post_winner, "the fault epoch re-advises onto the degraded winner");
    assert_eq!(report.switches.len(), 1, "exactly one switch: at the failure");
    assert_eq!(report.switches[0].epoch, FAULT_EPOCH);
    let resilience = report.resilience.as_ref().expect("fault-aware replay reports resilience");
    assert_eq!(resilience.recovery_epochs, Some(0), "recovery latency is bounded by the residual trigger");

    // the gated margin: adaptive beats EVERY static on the same degraded
    // accounting (statics accrue on the system in force too)
    for s in &report.statics {
        assert!(
            report.total_s < s.total_s * (1.0 - MARGIN / 2.0),
            "adaptive ({}) must beat static {} ({}) by the calibrated margin",
            report.total_s,
            s.strategy.label(),
            s.total_s
        );
    }
    assert!(report.win_vs_best_static > 0.0);

    // resilience accounting: degradation only ever hurts, and both fault
    // classes are itemized
    for l in &resilience.overall {
        assert!(l.faulted_s + 1e-12 >= l.healthy_s, "{} sped up under faults", l.strategy.label());
    }
    assert!(resilience.overall.iter().any(|l| l.loss > 0.0));
    let classes: Vec<&str> = resilience.classes.iter().map(|c| c.class).collect();
    assert_eq!(classes, ["rail-down", "congestion"]);

    // determinism: the full artifact is byte-stable across runs
    let again = replay_with_faults(&trace, &mode, &ReplayConfig::default(), Some(&spec)).unwrap();
    assert_eq!(report_to_json(&report), report_to_json(&again));
}

#[test]
fn static_replay_under_faults_never_switches_but_still_reports_loss() {
    let (pattern, pre_winner, _) = calibrate();
    let trace = stationary_trace(&pattern);
    let report =
        replay_with_faults(&trace, &ReplayMode::Static(pre_winner), &ReplayConfig::default(), Some(&schedule()))
            .unwrap();
    assert!(report.switches.is_empty());
    let resilience = report.resilience.as_ref().unwrap();
    assert_eq!(resilience.recovery_epochs, None, "a static policy never recovers");
    assert!(resilience.overall.iter().any(|l| l.loss > 0.0));
}

#[test]
fn zero_fault_entry_points_are_byte_identical() {
    // satellite safety rail, as a property over every synthetic scenario:
    // no schedule and an all-identity schedule are the same bytes as the
    // legacy path, with no fault vocabulary anywhere in the artifact
    for scenario in
        [TraceScenario::AmrDrift, TraceScenario::Sparsify, TraceScenario::Rebalance, TraceScenario::HaloBurst]
    {
        let trace = synthesize(scenario, "lassen", 4, 1, 17).unwrap();
        for sim in [false, true] {
            let config = ReplayConfig { sim, ..ReplayConfig::default() };
            let mode = ReplayMode::Adaptive { surface: None };
            let base = replay(&trace, &mode, &config).unwrap();
            let none = replay_with_faults(&trace, &mode, &config, None).unwrap();
            let identity = replay_with_faults(&trace, &mode, &config, Some(&FaultSpec::empty(99))).unwrap();
            let b = report_to_json(&base);
            assert_eq!(b, report_to_json(&none));
            assert_eq!(b, report_to_json(&identity));
            assert_eq!(render_report(&base), render_report(&identity));
            for token in ["fault", "residual", "resilience"] {
                assert!(!b.contains(token), "healthy artifact leaked {token:?}");
            }
        }
    }
}
