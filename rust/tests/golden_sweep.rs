//! Golden-output equivalence tests for the hot-path refactor: every emitter
//! byte must be independent of the execution path. The retained reference
//! pipeline ([`ExecMode::Reference`]: per-strategy schedule rebuild plus
//! the verbatim pre-refactor hash-map executor) and the compiled pipeline
//! must produce **byte-identical** sweep JSON/CSV, trace sweeps, replay
//! reports, and advisor surface artifacts under the same seeds — the
//! refactor changes time-to-answer, never the answer.

use hetcomm::advisor::{persist as surface_persist, DecisionSurface, SurfaceAxes};
use hetcomm::comm::{build_schedule, Strategy};
use hetcomm::sweep::emit::{to_csv, to_json};
use hetcomm::sweep::{run_sweep_mode, run_sweep_trace_mode, ExecMode, GridSpec, PatternGen, SweepConfig};
use hetcomm::trace::replay::{replay, report_to_json, ReplayConfig, ReplayMode};
use hetcomm::trace::scenarios::{synthesize, TraceScenario};

fn golden_config(machine: &str, dup: f64) -> SweepConfig {
    SweepConfig {
        grid: GridSpec {
            gens: vec![PatternGen::Uniform, PatternGen::Random],
            dest_nodes: vec![4, 8],
            gpus_per_node: vec![4],
            nics: vec![1],
            sizes: vec![1 << 8, 1 << 12, 1 << 16, 1 << 20],
            n_msgs: 48,
            dup_frac: dup,
        },
        seed: 2024,
        threads: 2,
        sim: true,
        machine: machine.into(),
        ..Default::default()
    }
}

#[test]
fn sweep_emitters_identical_across_executors() {
    for (machine, dup) in [("lassen", 0.0), ("lassen", 0.25), ("frontier-like", 0.0)] {
        let cfg = golden_config(machine, dup);
        let fast = run_sweep_mode(&cfg, ExecMode::Compiled).unwrap();
        let slow = run_sweep_mode(&cfg, ExecMode::Reference).unwrap();
        assert_eq!(to_json(&fast), to_json(&slow), "{machine} dup {dup}: JSON diverged");
        assert_eq!(to_csv(&fast), to_csv(&slow), "{machine} dup {dup}: CSV diverged");
        // and the compiled path is self-deterministic
        let again = run_sweep_mode(&cfg, ExecMode::Compiled).unwrap();
        assert_eq!(to_json(&fast), to_json(&again));
    }
}

#[test]
fn shaped_sweep_emitters_identical_across_executors() {
    // the NIC-rail axis must not open a gap between the two executors:
    // rail assignment and per-rail occupancy share one home
    for machine in ["lassen", "frontier-4nic"] {
        let mut cfg = golden_config(machine, 0.0);
        if machine == "lassen" {
            cfg.grid.nics = vec![1, 2, 4];
        }
        let fast = run_sweep_mode(&cfg, ExecMode::Compiled).unwrap();
        let slow = run_sweep_mode(&cfg, ExecMode::Reference).unwrap();
        assert_eq!(to_json(&fast), to_json(&slow), "{machine}: shaped JSON diverged");
        assert_eq!(to_csv(&fast), to_csv(&slow), "{machine}: shaped CSV diverged");
    }
}

#[test]
fn trace_sweep_emitters_identical_across_executors() {
    let trace = synthesize(TraceScenario::Sparsify, "lassen", 4, 1, 31).unwrap();
    let all = Strategy::all();
    let fast = run_sweep_trace_mode(&trace, &all, 2, true, ExecMode::Compiled).unwrap();
    let slow = run_sweep_trace_mode(&trace, &all, 2, true, ExecMode::Reference).unwrap();
    assert_eq!(to_json(&fast), to_json(&slow));
    assert_eq!(to_csv(&fast), to_csv(&slow));
}

#[test]
fn replay_sim_legs_match_reference_executor() {
    let trace = synthesize(TraceScenario::AmrDrift, "lassen", 4, 1, 7).unwrap();
    let mode = ReplayMode::Adaptive { surface: None };
    let report = replay(&trace, &mode, &ReplayConfig { sim: true, ..Default::default() }).unwrap();
    let params = trace.params().unwrap();
    for (row, epoch) in report.rows.iter().zip(&trace.epochs) {
        let schedule = build_schedule(row.strategy, &trace.machine, &epoch.pattern);
        let reference =
            hetcomm::sim::run_reference(&trace.machine, &params, &schedule, row.strategy.sim_ppn(&trace.machine));
        assert_eq!(
            row.sim_s.unwrap().to_bits(),
            reference.total.to_bits(),
            "epoch {}: replay sim leg diverged from the reference executor",
            row.index
        );
    }
    // report bytes stay deterministic
    let again = replay(&trace, &mode, &ReplayConfig { sim: true, ..Default::default() }).unwrap();
    assert_eq!(report_to_json(&report), report_to_json(&again));
}

#[test]
fn surface_artifacts_unchanged_by_the_refactor_machinery() {
    // surfaces are model-driven (no simulator leg) — two compiles must stay
    // byte-identical, and labels survive the &'static str migration
    let axes = SurfaceAxes {
        msgs: vec![64, 256],
        sizes: vec![1 << 8, 1 << 12, 1 << 16],
        dest_nodes: vec![4, 16],
        gpus_per_node: vec![4],
    };
    let a = DecisionSurface::compile("lassen", axes.clone(), 0.0).unwrap();
    let b = DecisionSurface::compile("lassen", axes, 0.0).unwrap();
    let (ja, jb) = (surface_persist::to_json(&a), surface_persist::to_json(&b));
    assert_eq!(ja, jb);
    for s in Strategy::all() {
        assert!(ja.contains(&format!("\"{}\"", s.label())), "missing {}", s.label());
    }
}
