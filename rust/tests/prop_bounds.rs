//! Pruning-soundness property suite for [`hetcomm::model::bounds`]: the
//! branch-and-bound sweep (`sweep --prune`) skips a strategy's simulation
//! whenever its lower bound exceeds the cell incumbent's simulated time, so
//! winner preservation rests on exactly two inequalities, checked here over
//! randomized patterns, node shapes and sizes:
//!
//! 1. `lower <= model_time <= upper` — the envelope brackets the Table 6
//!    closed forms (the upper bound seeds the search, the model winner is
//!    always in-interval);
//! 2. `lower <= sim_time` — the discrete-event executor can never finish a
//!    schedule below the bound (the pruning oracle: a skipped strategy
//!    could not have won the cell).
//!
//! Plus bound-tightness monotonicity: the `[lower, upper]` gap never
//! shrinks as message size grows, so coarse-grid refinement seeds stay
//! conservative.

use hetcomm::comm::{build_schedule, dedup, Strategy};
use hetcomm::model::{BoundModel, StrategyModel};
use hetcomm::pattern::generators::{random_pattern, Scenario};
use hetcomm::sweep::GridSpec;
use hetcomm::topology::machines;
use hetcomm::util::rng::Rng;

/// (machine preset, NIC rails) shapes spanning the registry: 2-socket
/// single-rail, multi-rail overrides of it, and the shape-pinned 4-rail
/// preset on its own pinned count.
const SHAPES: [(&str, usize); 4] = [("lassen", 1), ("lassen", 2), ("frontier-like", 1), ("frontier-4nic", 4)];

#[test]
fn bounds_bracket_model_on_uniform_and_random_patterns() {
    for &(name, nics) in &SHAPES {
        let (arch, params) = machines::parse(name, 1).unwrap();
        let bm = BoundModel::new(&arch, &params);
        for dest in [3, 16] {
            let machine = GridSpec::default().machine_for_arch(&arch, dest, 4, nics);
            let sm = StrategyModel::new(&machine, &params);
            let bm_m = BoundModel::new(&machine, &params);
            let ppn = machine.cores_per_node();
            for n_msgs in [16, 177] {
                for exp in 0..21 {
                    for dup in [0.0, 0.3] {
                        let sc = Scenario { n_msgs, msg_size: 1usize << exp, n_dest: dest, dup_frac: dup };
                        let inputs = sc.inputs(&machine, ppn);
                        for s in Strategy::all() {
                            let b = bm_m.bounds(s, &inputs);
                            let t = sm.time(s, &inputs);
                            assert!(
                                b.lower <= t && t <= b.upper,
                                "{name}/{nics}r {}: model {t:e} outside [{:e}, {:e}] \
                                 (msgs {n_msgs}, size 2^{exp}, dup {dup})",
                                s.label(),
                                b.lower,
                                b.upper
                            );
                            assert!(b.lower.is_finite() && b.upper.is_finite());
                            assert!(b.lower > 0.0, "{}: zero lower bound prunes nothing", s.label());
                        }
                    }
                }
            }
        }
        // the arch-level model (no grid resizing) brackets too
        let inputs = Scenario { n_msgs: 32, msg_size: 4096, n_dest: 4, dup_frac: 0.0 }
            .inputs(&arch, arch.cores_per_node());
        let sm = StrategyModel::new(&arch, &params);
        for s in Strategy::all() {
            let b = bm.bounds(s, &inputs);
            let t = sm.time(s, &inputs);
            assert!(b.lower <= t && t <= b.upper, "{name}: arch-level bracket failed for {}", s.label());
        }
    }
}

#[test]
fn lower_bound_never_exceeds_simulated_time() {
    // The oracle behind pruning: over random patterns (irregular fan-out,
    // random sizes, duplicates) on every shape, the executor's total can
    // never undercut the bound. `>=` must hold bit-for-bit — one epsilon
    // here is a wrongly pruned winner in a million-cell study.
    for &(name, nics) in &SHAPES {
        let (arch, params) = machines::parse(name, 1).unwrap();
        for dest in [4, 9] {
            let machine = GridSpec::default().machine_for_arch(&arch, dest, 4, nics);
            let bm = BoundModel::new(&machine, &params);
            let ppn = machine.cores_per_node();
            let mut rng = Rng::new(0x5eed ^ ((dest as u64) << 8) ^ nics as u64);
            for case in 0..6 {
                let n_msgs = 8 + 31 * case;
                let max_bytes = 1usize << (4 + 2 * case);
                let dup = if case % 2 == 0 { 0.0 } else { 0.4 };
                let pattern = random_pattern(&machine, &mut rng, n_msgs, max_bytes, dup);
                let inputs = pattern.model_inputs(&machine, ppn, pattern.duplicate_fraction(&machine));
                for s in Strategy::all() {
                    let b = bm.bounds(s, &inputs);
                    let schedule = build_schedule(s, &machine, &pattern);
                    let sim = hetcomm::sim::run_reference(&machine, &params, &schedule, s.sim_ppn(&machine)).total;
                    assert!(
                        b.lower <= sim,
                        "{name}/{nics}r {}: lower bound {:e} exceeds simulated {sim:e} \
                         (case {case}: msgs {n_msgs}, max {max_bytes} B, dup {dup}) — pruning is unsound",
                        s.label(),
                        b.lower
                    );
                }
            }
        }
    }
}

#[test]
fn lower_bound_never_exceeds_simulated_time_on_uniform_grids() {
    // The exact workload shape `--prune` runs on: uniform scenarios across
    // the size axis, with and without marked duplicates.
    let (arch, params) = machines::parse("lassen", 1).unwrap();
    for nics in [1, 4] {
        let machine = GridSpec::default().machine_for_arch(&arch, 4, 4, nics);
        let bm = BoundModel::new(&machine, &params);
        for dup in [0.0, 0.25] {
            for exp in [4, 10, 16, 20] {
                let sc = Scenario { n_msgs: 96, msg_size: 1usize << exp, n_dest: 4, dup_frac: dup };
                let base = sc.materialize(&machine);
                let pattern =
                    if dup > 0.0 { dedup::with_duplicate_fraction(&machine, &base, dup) } else { base };
                let inputs = sc.inputs(&machine, machine.cores_per_node());
                for s in Strategy::all() {
                    let b = bm.bounds(s, &inputs);
                    let schedule = build_schedule(s, &machine, &pattern);
                    let sim = hetcomm::sim::run_reference(&machine, &params, &schedule, s.sim_ppn(&machine)).total;
                    assert!(
                        b.lower <= sim,
                        "{}/{nics}r: lower {:e} > sim {sim:e} (size 2^{exp}, dup {dup})",
                        s.label(),
                        b.lower
                    );
                }
            }
        }
    }
}

#[test]
fn bound_gap_is_monotone_in_message_size() {
    // Tightness monotonicity: growing the per-message size never shrinks
    // the [lower, upper] interval, so a bound computed at a coarse lattice
    // point stays conservative for the finer sizes refinement visits.
    for &(name, nics) in &SHAPES {
        let (arch, params) = machines::parse(name, 1).unwrap();
        let machine = GridSpec::default().machine_for_arch(&arch, 8, 4, nics);
        let bm = BoundModel::new(&machine, &params);
        let ppn = machine.cores_per_node();
        for s in Strategy::all() {
            let mut prev_gap = 0.0f64;
            for exp in 0..21 {
                let sc = Scenario { n_msgs: 64, msg_size: 1usize << exp, n_dest: 8, dup_frac: 0.0 };
                let b = bm.bounds(s, &sc.inputs(&machine, ppn));
                let gap = b.upper - b.lower;
                assert!(
                    gap >= prev_gap - 1e-15,
                    "{name}/{nics}r {}: gap shrank from {prev_gap:e} to {gap:e} at size 2^{exp}",
                    s.label()
                );
                prev_gap = gap;
            }
        }
    }
}
