//! Pruning-soundness property suite for [`hetcomm::model::bounds`]: the
//! branch-and-bound sweep (`sweep --prune`) skips a strategy's simulation
//! whenever its lower bound exceeds the cell incumbent's simulated time, so
//! winner preservation rests on exactly two inequalities, checked here over
//! randomized patterns, node shapes and sizes:
//!
//! 1. `lower <= model_time <= upper` — the envelope brackets the Table 6
//!    closed forms (the upper bound seeds the search, the model winner is
//!    always in-interval);
//! 2. `lower <= sim_time` — the discrete-event executor can never finish a
//!    schedule below the bound (the pruning oracle: a skipped strategy
//!    could not have won the cell).
//!
//! Plus bound-tightness monotonicity: the `[lower, upper]` gap never
//! shrinks as message size grows, so coarse-grid refinement seeds stay
//! conservative.
//!
//! The same two inequalities back the collective layer's pruning
//! ([`hetcomm::collective::ColBoundModel`], `collective --prune`), checked
//! here over the (collective × algorithm × nodes × size) product and over
//! seeded alltoallv lowerings against the reference executor. The suite
//! also pins the advisor's lane-vectorized batch interpolator (`simd`
//! feature) to its scalar twin bit for bit.

use hetcomm::advisor::{DecisionSurface, Pattern, SurfaceAxes};
use hetcomm::collective::{
    algorithm_time, lower, sim_schedule, Collective, CollectiveAlgorithm, CollectiveSpec, ColBoundModel,
};
use hetcomm::comm::{build_schedule, dedup, Strategy};
use hetcomm::model::{BoundModel, StrategyModel};
use hetcomm::pattern::generators::{random_pattern, Scenario};
use hetcomm::sweep::GridSpec;
use hetcomm::topology::machines;
use hetcomm::util::rng::Rng;

/// (machine preset, NIC rails) shapes spanning the registry: 2-socket
/// single-rail, multi-rail overrides of it, and the shape-pinned 4-rail
/// preset on its own pinned count.
const SHAPES: [(&str, usize); 4] = [("lassen", 1), ("lassen", 2), ("frontier-like", 1), ("frontier-4nic", 4)];

#[test]
fn bounds_bracket_model_on_uniform_and_random_patterns() {
    for &(name, nics) in &SHAPES {
        let (arch, params) = machines::parse(name, 1).unwrap();
        let bm = BoundModel::new(&arch, &params);
        for dest in [3, 16] {
            let machine = GridSpec::default().machine_for_arch(&arch, dest, 4, nics);
            let sm = StrategyModel::new(&machine, &params);
            let bm_m = BoundModel::new(&machine, &params);
            let ppn = machine.cores_per_node();
            for n_msgs in [16, 177] {
                for exp in 0..21 {
                    for dup in [0.0, 0.3] {
                        let sc = Scenario { n_msgs, msg_size: 1usize << exp, n_dest: dest, dup_frac: dup };
                        let inputs = sc.inputs(&machine, ppn);
                        for s in Strategy::all() {
                            let b = bm_m.bounds(s, &inputs);
                            let t = sm.time(s, &inputs);
                            assert!(
                                b.lower <= t && t <= b.upper,
                                "{name}/{nics}r {}: model {t:e} outside [{:e}, {:e}] \
                                 (msgs {n_msgs}, size 2^{exp}, dup {dup})",
                                s.label(),
                                b.lower,
                                b.upper
                            );
                            assert!(b.lower.is_finite() && b.upper.is_finite());
                            assert!(b.lower > 0.0, "{}: zero lower bound prunes nothing", s.label());
                        }
                    }
                }
            }
        }
        // the arch-level model (no grid resizing) brackets too
        let inputs = Scenario { n_msgs: 32, msg_size: 4096, n_dest: 4, dup_frac: 0.0 }
            .inputs(&arch, arch.cores_per_node());
        let sm = StrategyModel::new(&arch, &params);
        for s in Strategy::all() {
            let b = bm.bounds(s, &inputs);
            let t = sm.time(s, &inputs);
            assert!(b.lower <= t && t <= b.upper, "{name}: arch-level bracket failed for {}", s.label());
        }
    }
}

#[test]
fn lower_bound_never_exceeds_simulated_time() {
    // The oracle behind pruning: over random patterns (irregular fan-out,
    // random sizes, duplicates) on every shape, the executor's total can
    // never undercut the bound. `>=` must hold bit-for-bit — one epsilon
    // here is a wrongly pruned winner in a million-cell study.
    for &(name, nics) in &SHAPES {
        let (arch, params) = machines::parse(name, 1).unwrap();
        for dest in [4, 9] {
            let machine = GridSpec::default().machine_for_arch(&arch, dest, 4, nics);
            let bm = BoundModel::new(&machine, &params);
            let ppn = machine.cores_per_node();
            let mut rng = Rng::new(0x5eed ^ ((dest as u64) << 8) ^ nics as u64);
            for case in 0..6 {
                let n_msgs = 8 + 31 * case;
                let max_bytes = 1usize << (4 + 2 * case);
                let dup = if case % 2 == 0 { 0.0 } else { 0.4 };
                let pattern = random_pattern(&machine, &mut rng, n_msgs, max_bytes, dup);
                let inputs = pattern.model_inputs(&machine, ppn, pattern.duplicate_fraction(&machine));
                for s in Strategy::all() {
                    let b = bm.bounds(s, &inputs);
                    let schedule = build_schedule(s, &machine, &pattern);
                    let sim = hetcomm::sim::run_reference(&machine, &params, &schedule, s.sim_ppn(&machine)).total;
                    assert!(
                        b.lower <= sim,
                        "{name}/{nics}r {}: lower bound {:e} exceeds simulated {sim:e} \
                         (case {case}: msgs {n_msgs}, max {max_bytes} B, dup {dup}) — pruning is unsound",
                        s.label(),
                        b.lower
                    );
                }
            }
        }
    }
}

#[test]
fn lower_bound_never_exceeds_simulated_time_on_uniform_grids() {
    // The exact workload shape `--prune` runs on: uniform scenarios across
    // the size axis, with and without marked duplicates.
    let (arch, params) = machines::parse("lassen", 1).unwrap();
    for nics in [1, 4] {
        let machine = GridSpec::default().machine_for_arch(&arch, 4, 4, nics);
        let bm = BoundModel::new(&machine, &params);
        for dup in [0.0, 0.25] {
            for exp in [4, 10, 16, 20] {
                let sc = Scenario { n_msgs: 96, msg_size: 1usize << exp, n_dest: 4, dup_frac: dup };
                let base = sc.materialize(&machine);
                let pattern =
                    if dup > 0.0 { dedup::with_duplicate_fraction(&machine, &base, dup) } else { base };
                let inputs = sc.inputs(&machine, machine.cores_per_node());
                for s in Strategy::all() {
                    let b = bm.bounds(s, &inputs);
                    let schedule = build_schedule(s, &machine, &pattern);
                    let sim = hetcomm::sim::run_reference(&machine, &params, &schedule, s.sim_ppn(&machine)).total;
                    assert!(
                        b.lower <= sim,
                        "{}/{nics}r: lower {:e} > sim {sim:e} (size 2^{exp}, dup {dup})",
                        s.label(),
                        b.lower
                    );
                }
            }
        }
    }
}

#[test]
fn collective_bounds_bracket_algorithm_model() {
    // The collective analogue of the bracket above: for every collective ×
    // lowering algorithm × node count × block size, the composed stage
    // envelope of `ColBoundModel` contains the Table 6 model time the
    // sweep ranks by. The upper bound seeds `collective --prune`'s search,
    // so a model time above it would desynchronize the incumbent.
    for name in ["lassen", "frontier-like"] {
        let (arch, params) = machines::parse(name, 1).unwrap();
        for nodes in [2, 4, 16] {
            let machine = machines::with_shape(&arch, nodes, 4);
            let bm = ColBoundModel::new(&machine, &params);
            for collective in Collective::ALL {
                for exp in [6, 10, 14, 18] {
                    let direct = CollectiveSpec::new(collective, 1usize << exp, 11).materialize(&machine);
                    for alg in CollectiveAlgorithm::ALL {
                        let lowering = lower(collective, alg, &machine, &direct);
                        let b = bm.bounds(&lowering);
                        let t = algorithm_time(&machine, &params, &lowering);
                        assert!(
                            b.lower <= t && t <= b.upper,
                            "{name} {}/{} on {nodes}n: model {t:e} outside [{:e}, {:e}] (block 2^{exp})",
                            collective.label(),
                            alg.label(),
                            b.lower,
                            b.upper
                        );
                        assert!(b.lower.is_finite() && b.upper.is_finite());
                        assert!(b.lower > 0.0, "{}: zero lower bound prunes nothing", alg.label());
                    }
                }
            }
        }
    }
}

#[test]
fn collective_lower_bound_never_exceeds_simulated_time() {
    // The pruning oracle for `collective --prune`: over seeded alltoallv
    // patterns (the irregular member of the family — random per-pair block
    // scaling), the reference executor's total for a lowering's staged
    // schedule never undercuts the lowering's lower bound. A violation
    // here is a wrongly skipped algorithm in a pruned collective sweep.
    let (arch, params) = machines::parse("lassen", 1).unwrap();
    for nodes in [2, 8] {
        let machine = machines::with_shape(&arch, nodes, 4);
        let bm = ColBoundModel::new(&machine, &params);
        for seed in [1u64, 7, 42] {
            for exp in [9, 13, 17] {
                let direct =
                    CollectiveSpec::new(Collective::Alltoallv, 1usize << exp, seed).materialize(&machine);
                for alg in CollectiveAlgorithm::ALL {
                    let lowering = lower(Collective::Alltoallv, alg, &machine, &direct);
                    let b = bm.bounds(&lowering);
                    let schedule = sim_schedule(&machine, &lowering);
                    let sim =
                        hetcomm::sim::run_reference(&machine, &params, &schedule, machine.gpus_per_node())
                            .total;
                    assert!(
                        b.lower <= sim,
                        "alltoallv/{} on {nodes}n: lower {:e} > sim {sim:e} \
                         (seed {seed}, block 2^{exp}) — collective pruning is unsound",
                        alg.label(),
                        b.lower
                    );
                }
            }
        }
    }
}

#[test]
fn lane_batch_lookup_matches_scalar_lookup_bit_for_bit() {
    // The `simd` feature's contract: `lookup_batch` answers are
    // bit-identical to per-query `lookup` regardless of which inner loop
    // ran. `lookup_batch_lanes` pins the four-wide lane path from a
    // default build; `lookup_batch` covers whichever path the feature
    // selected. Random batches over shaped surfaces, clamped and
    // in-lattice queries alike.
    for &(name, nics) in &SHAPES {
        // Pinned presets reject explicit NIC overrides; 0 means "own count".
        let nic_arg = if name == "frontier-4nic" { 0 } else { nics };
        let axes = SurfaceAxes {
            msgs: vec![8, 64, 512],
            sizes: vec![1 << 6, 1 << 10, 1 << 14, 1 << 18],
            dest_nodes: vec![2, 8],
            gpus_per_node: vec![4],
        };
        let surface = DecisionSurface::compile_shaped(name, nic_arg, axes, 0.0).unwrap();
        let mut rng = Rng::new(0xba7c4 ^ ((nics as u64) << 16));
        let queries: Vec<Pattern> = (0..257)
            .map(|_| Pattern {
                n_msgs: 1 + (rng.next_u64() % 2048) as usize,
                msg_size: 1usize << (rng.next_u64() % 22),
                dest_nodes: 1 + (rng.next_u64() % 40) as usize,
                gpus_per_node: 4,
            })
            .collect();
        let lanes = surface.lookup_batch_lanes(&queries);
        let batch = surface.lookup_batch(&queries);
        assert_eq!(lanes.len(), queries.len());
        for (i, q) in queries.iter().enumerate() {
            let single = surface.lookup(q);
            assert_eq!(single.ranked.len(), lanes[i].ranked.len());
            for ((s0, t0), (s1, t1)) in single.ranked.iter().zip(&lanes[i].ranked) {
                assert_eq!(s0, s1, "{name}/{nics}r query {i}: lane path reordered strategies");
                assert_eq!(
                    t0.to_bits(),
                    t1.to_bits(),
                    "{name}/{nics}r query {i} {}: lane time {t1:e} != scalar {t0:e}",
                    s0.label()
                );
            }
            for ((s0, t0), (s1, t1)) in single.ranked.iter().zip(&batch[i].ranked) {
                assert_eq!(s0, s1);
                assert_eq!(t0.to_bits(), t1.to_bits(), "{name}/{nics}r query {i}: lookup_batch diverged");
            }
        }
    }
}

#[test]
fn bound_gap_is_monotone_in_message_size() {
    // Tightness monotonicity: growing the per-message size never shrinks
    // the [lower, upper] interval, so a bound computed at a coarse lattice
    // point stays conservative for the finer sizes refinement visits.
    for &(name, nics) in &SHAPES {
        let (arch, params) = machines::parse(name, 1).unwrap();
        let machine = GridSpec::default().machine_for_arch(&arch, 8, 4, nics);
        let bm = BoundModel::new(&machine, &params);
        let ppn = machine.cores_per_node();
        for s in Strategy::all() {
            let mut prev_gap = 0.0f64;
            for exp in 0..21 {
                let sc = Scenario { n_msgs: 64, msg_size: 1usize << exp, n_dest: 8, dup_frac: 0.0 };
                let b = bm.bounds(s, &sc.inputs(&machine, ppn));
                let gap = b.upper - b.lower;
                assert!(
                    gap >= prev_gap - 1e-15,
                    "{name}/{nics}r {}: gap shrank from {prev_gap:e} to {gap:e} at size 2^{exp}",
                    s.label()
                );
                prev_gap = gap;
            }
        }
    }
}
