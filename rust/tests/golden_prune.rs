//! Golden winner-preservation tests for the sweep scale levers: pruning,
//! pattern reuse and adaptive refinement must never change a winner,
//! crossover or regime report — only which simulations get paid for.
//!
//! - **reuse** is exact (one unit-size lowering rescaled per cell), so the
//!   *entire* emitted JSON must be byte-identical to the legacy run;
//! - **prune** adds `sim_pruned`/`pruned` fields and drops pruned `sim_s`
//!   values, so the comparison is on the derived report sections:
//!   crossovers and regimes byte-for-byte, winners byte-for-byte after
//!   stripping the per-cell prune counter;
//! - **refine** emits a subset of cells at their full-grid seeds, so every
//!   emitted winner row must appear verbatim in the exhaustive run's JSON,
//!   with crossovers and regimes byte-identical (the boundary is resolved
//!   to full resolution).
//!
//! All of it across 1/2/4-rail node shapes and `--threads 1` vs `4`.

use hetcomm::sweep::emit::to_json;
use hetcomm::sweep::{run_sweep, GridSpec, PatternGen, SweepConfig};

fn pinned_config(machine: &str, nics: Vec<usize>, threads: usize) -> SweepConfig {
    SweepConfig {
        grid: GridSpec {
            gens: vec![PatternGen::Uniform, PatternGen::Random],
            dest_nodes: vec![4, 8],
            gpus_per_node: vec![4],
            nics,
            sizes: vec![1 << 6, 1 << 10, 1 << 14, 1 << 18],
            n_msgs: 192,
            dup_frac: 0.0,
        },
        seed: 2025,
        threads,
        sim: true,
        machine: machine.into(),
        ..Default::default()
    }
}

/// Extract one top-level JSON array section (`"winners": [...]`) verbatim.
fn section<'a>(json: &'a str, key: &str) -> &'a str {
    let open = format!("  \"{key}\": [\n");
    let start = json.find(&open).unwrap_or_else(|| panic!("section {key} missing")) + open.len();
    let end = start + json[start..].find("  ],").unwrap_or_else(|| panic!("section {key} unterminated"));
    &json[start..end]
}

/// Drop `, "pruned": N` from each winner row so pruned and exhaustive runs
/// compare on the winner content alone.
fn strip_prune_counts(rows: &str) -> String {
    rows.lines()
        .map(|line| match line.find(", \"pruned\":") {
            Some(pos) => {
                let close = pos + line[pos..].find('}').expect("well-formed row");
                format!("{}{}", &line[..pos], &line[close..])
            }
            None => line.to_string(),
        })
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn pattern_reuse_emits_byte_identical_json() {
    for (machine, nics) in [("lassen", vec![1]), ("lassen", vec![1, 2, 4]), ("frontier-4nic", vec![1])] {
        let base = pinned_config(machine, nics, 4);
        let legacy = run_sweep(&base).unwrap();
        let mut cfg = base.clone();
        cfg.reuse_patterns = true;
        let reused = run_sweep(&cfg).unwrap();
        assert_eq!(to_json(&legacy), to_json(&reused), "{machine}: reuse changed a byte");
        cfg.threads = 1;
        let serial = run_sweep(&cfg).unwrap();
        assert_eq!(to_json(&reused), to_json(&serial), "{machine}: thread count changed reused bytes");
    }
}

#[test]
fn pruned_sweeps_preserve_winner_crossover_regime_reports() {
    for (machine, nics) in [("lassen", vec![1]), ("lassen", vec![2]), ("lassen", vec![4]), ("frontier-4nic", vec![1])] {
        let full = run_sweep(&pinned_config(machine, nics.clone(), 4)).unwrap();
        let mut cfg = pinned_config(machine, nics, 4);
        cfg.prune = true;
        cfg.reuse_patterns = true;
        let pruned = run_sweep(&cfg).unwrap();
        let (fj, pj) = (to_json(&full), to_json(&pruned));
        assert_eq!(
            section(&fj, "winners"),
            strip_prune_counts(section(&pj, "winners")).as_str(),
            "{machine}: pruning changed a winner row"
        );
        assert_eq!(section(&fj, "crossovers"), section(&pj, "crossovers"), "{machine}: crossovers moved");
        assert_eq!(section(&fj, "regimes"), section(&pj, "regimes"), "{machine}: regimes moved");
        // determinism of the pruned emission itself across thread counts
        cfg.threads = 1;
        let serial = run_sweep(&cfg).unwrap();
        assert_eq!(pj, to_json(&serial), "{machine}: thread count changed pruned bytes");
        // and this grid prunes for real on the small sizes
        assert!(pruned.report.prune.pruned > 0, "{machine}: nothing pruned on the golden grid");
    }
}

#[test]
fn refined_sweeps_resolve_the_same_boundary() {
    // a size-rich line so depth-2 refinement recurses rather than degenerates
    let mut base = pinned_config("lassen", vec![1], 4);
    base.grid.gens = vec![PatternGen::Uniform];
    base.grid.sizes = (6..15).map(|e| 1usize << e).collect();
    let full = run_sweep(&base).unwrap();
    let mut cfg = base.clone();
    cfg.refine = 2;
    let refined = run_sweep(&cfg).unwrap();
    let (fj, rj) = (to_json(&full), to_json(&refined));
    assert_eq!(section(&fj, "crossovers"), section(&rj, "crossovers"), "refinement lost a crossover");
    // Regime winners must agree; the band totals legitimately sum over
    // fewer lattice points in a refined run, so compare winner fields only.
    let regime_key =
        |g: &hetcomm::sweep::RegimeWinner| (g.gen, g.dest_nodes, g.gpus_per_node, g.nics, g.band, g.winner);
    assert_eq!(
        full.report.regimes.iter().map(regime_key).collect::<Vec<_>>(),
        refined.report.regimes.iter().map(regime_key).collect::<Vec<_>>(),
        "refinement changed a regime winner"
    );
    // every refined winner row coincides bit-for-bit with the exhaustive run
    let full_rows: std::collections::BTreeSet<&str> =
        section(&fj, "winners").lines().map(|l| l.trim_end_matches(',')).collect();
    for row in section(&rj, "winners").lines() {
        assert!(full_rows.contains(row.trim_end_matches(',')), "refined row not in exhaustive run: {row}");
    }
    assert!(refined.cells.len() < full.cells.len(), "depth-2 refinement must skip interior cells");
    // thread invariance of the refinement wavefront
    cfg.threads = 1;
    let serial = run_sweep(&cfg).unwrap();
    assert_eq!(rj, to_json(&serial), "thread count changed refined bytes");
}
