//! Property tests over the communication strategies: conservation,
//! message-count orderings and duplicate-data invariants on random
//! irregular patterns.

use hetcomm::comm::{build_schedule, is_internode, Loc, Strategy, StrategyKind, Transport};
use hetcomm::pattern::generators::random_pattern;
use hetcomm::pattern::CommPattern;
use hetcomm::topology::machines::lassen;
use hetcomm::topology::Machine;
use hetcomm::util::prop::{check, Gen};

fn machine_for(g: &mut Gen) -> Machine {
    lassen(g.usize(2, 6))
}

fn ppn_for(machine: &Machine, s: Strategy) -> usize {
    match s.kind {
        StrategyKind::SplitMd | StrategyKind::SplitDd => machine.cores_per_node(),
        _ => machine.gpus_per_node() * s.kind.ppg(),
    }
}

/// Unique inter-node bytes required by a pattern (per destination node).
fn required_internode_unique(machine: &Machine, p: &CommPattern) -> usize {
    let mut seen = std::collections::BTreeSet::new();
    let mut total = 0;
    for m in p.internode(machine) {
        if m.dup_group == hetcomm::pattern::Msg::NO_DUP
            || seen.insert((m.src, m.dup_group, machine.gpu_node(m.dst)))
        {
            total += m.bytes;
        }
    }
    total
}

#[test]
fn strategy_kind_parse_roundtrips_display() {
    check("StrategyKind::parse inverts Display", 200, |g| {
        let kind = *g.choose(&StrategyKind::ALL);
        let shown = kind.to_string();
        // the exact display name and any case-jittered variant must parse back
        let jittered: String = shown
            .chars()
            .map(|c| if g.bool(0.5) { c.to_ascii_uppercase() } else { c.to_ascii_lowercase() })
            .collect();
        for cand in [shown.as_str(), jittered.as_str()] {
            match StrategyKind::parse(cand) {
                Some(k) if k == kind => {}
                other => return Err(format!("{cand:?} parsed to {other:?}, want {kind:?}")),
            }
        }
        // and full labels round-trip through Strategy::parse_label
        let strategy = *g.choose(&Strategy::all());
        if Strategy::parse_label(&strategy.label()) != Some(strategy) {
            return Err(format!("label {:?} does not round-trip", strategy.label()));
        }
        Ok(())
    });
}

#[test]
fn internode_bytes_conserved_per_strategy() {
    check("internode bytes == unique requirement", 60, |g| {
        let machine = machine_for(g);
        let n_msgs = g.usize(1, 80);
        let pattern = random_pattern(&machine, g.rng(), n_msgs, 1 << 14, 0.3);
        let required = required_internode_unique(&machine, &pattern);
        let raw: usize = pattern.internode(&machine).map(|m| m.bytes).sum();
        for s in Strategy::all() {
            let sched = build_schedule(s, &machine, &pattern);
            let ppn = ppn_for(&machine, s);
            let got = sched.internode_bytes(&machine, ppn);
            let expect = if s.kind == StrategyKind::Standard { raw } else { required };
            if got != expect {
                return Err(format!("{}: internode bytes {got} != expected {expect}", s.label()));
            }
        }
        Ok(())
    });
}

#[test]
fn message_count_ordering() {
    check("standard >= 2-step >= 3-step inter-node msgs", 60, |g| {
        let machine = machine_for(g);
        let n_msgs = g.usize(2, 100);
        let pattern = random_pattern(&machine, g.rng(), n_msgs, 1 << 12, 0.2);
        let count = |kind| {
            let s = Strategy::new(kind, Transport::DeviceAware).unwrap();
            let sched = build_schedule(s, &machine, &pattern);
            sched.internode_msgs(&machine, ppn_for(&machine, s))
        };
        let std_n = count(StrategyKind::Standard);
        let two_n = count(StrategyKind::TwoStep);
        let three_n = count(StrategyKind::ThreeStep);
        if !(std_n >= two_n && two_n >= three_n) {
            return Err(format!("ordering violated: std {std_n}, 2-step {two_n}, 3-step {three_n}"));
        }
        Ok(())
    });
}

#[test]
fn three_step_at_most_one_buffer_per_node_pair() {
    check("3-step single buffer per pair", 40, |g| {
        let machine = machine_for(g);
        let n = g.usize(1, 120);
        let pattern = random_pattern(&machine, g.rng(), n, 1 << 13, 0.2);
        let s = Strategy::new(StrategyKind::ThreeStep, Transport::Staged).unwrap();
        let sched = build_schedule(s, &machine, &pattern);
        let ppn = ppn_for(&machine, s);
        let mut pairs = std::collections::BTreeMap::new();
        for ph in &sched.phases {
            for x in &ph.xfers {
                if is_internode(&machine, x, ppn) {
                    let node = |l: Loc| match l {
                        Loc::Gpu(gp) => machine.gpu_node(gp).0,
                        Loc::Host(p) => machine.proc_node(p, ppn).0,
                    };
                    *pairs.entry((node(x.src), node(x.dst))).or_insert(0usize) += 1;
                }
            }
        }
        for ((a, b), n) in pairs {
            if n > 1 {
                return Err(format!("pair ({a},{b}) has {n} inter-node messages"));
            }
        }
        Ok(())
    });
}

#[test]
fn split_respects_cap_modulo_raise() {
    check("split chunks <= effective cap", 40, |g| {
        let machine = machine_for(g);
        let n = g.usize(1, 60);
        let pattern = random_pattern(&machine, g.rng(), n, 1 << 16, 0.1);
        let cap = *g.choose(&[1024usize, 4096, 8192, 16384]);
        let s = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap().with_cap(cap);
        let sched = build_schedule(s, &machine, &pattern);
        let ppn = machine.cores_per_node();
        // effective cap may be raised to ceil(total_node_vol / ppn)
        let stats = pattern.stats(&machine);
        let raised = stats.s_node.div_ceil(ppn);
        let eff = cap.max(raised);
        for ph in sched.phases.iter().filter(|p| p.label == "inter-node") {
            for x in &ph.xfers {
                if x.bytes > eff {
                    return Err(format!("chunk {} > effective cap {eff}", x.bytes));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn device_aware_schedules_have_no_copies() {
    check("DA schedules copy-free", 30, |g| {
        let machine = machine_for(g);
        let n = g.usize(1, 50);
        let pattern = random_pattern(&machine, g.rng(), n, 1 << 12, 0.2);
        for kind in [StrategyKind::Standard, StrategyKind::ThreeStep, StrategyKind::TwoStep] {
            let s = Strategy::new(kind, Transport::DeviceAware).unwrap();
            let sched = build_schedule(s, &machine, &pattern);
            if sched.phases.iter().any(|p| !p.copies.is_empty()) {
                return Err(format!("{} has copies", s.label()));
            }
            // all endpoints are GPUs
            for ph in &sched.phases {
                for x in &ph.xfers {
                    if matches!(x.src, Loc::Host(_)) || matches!(x.dst, Loc::Host(_)) {
                        return Err(format!("{} routes through host", s.label()));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn staged_copies_balance_delivery() {
    check("staged d2h == h2d bytes", 40, |g| {
        let machine = machine_for(g);
        let n = g.usize(1, 60);
        let pattern = random_pattern(&machine, g.rng(), n, 1 << 12, 0.0);
        for kind in [StrategyKind::Standard, StrategyKind::ThreeStep, StrategyKind::TwoStep] {
            let s = Strategy::new(kind, Transport::Staged).unwrap();
            let sched = build_schedule(s, &machine, &pattern);
            let d2h: usize = sched
                .phases
                .iter()
                .flat_map(|p| &p.copies)
                .filter(|c| c.dir == hetcomm::comm::CopyKind::D2H)
                .map(|c| c.bytes)
                .sum();
            let h2d: usize = sched
                .phases
                .iter()
                .flat_map(|p| &p.copies)
                .filter(|c| c.dir == hetcomm::comm::CopyKind::H2D)
                .map(|c| c.bytes)
                .sum();
            // without duplicates, staged-out == delivered-in
            if d2h != h2d {
                return Err(format!("{}: d2h {d2h} != h2d {h2d}", s.label()));
            }
        }
        Ok(())
    });
}

#[test]
fn empty_and_intranode_patterns_cross_nothing() {
    check("no internode traffic without internode msgs", 30, |g| {
        let machine = lassen(g.usize(2, 4));
        // all messages within node 0
        let gpn = machine.gpus_per_node();
        let mut msgs = Vec::new();
        for _ in 0..g.usize(1, 20) {
            let a = g.usize(0, gpn);
            let mut b = g.usize(0, gpn);
            while b == a {
                b = g.usize(0, gpn);
            }
            msgs.push(hetcomm::pattern::Msg::new(
                hetcomm::topology::GpuId(a),
                hetcomm::topology::GpuId(b),
                g.usize(1, 1 << 10),
            ));
        }
        let pattern = CommPattern::new(msgs);
        for s in Strategy::all() {
            let sched = build_schedule(s, &machine, &pattern);
            let n = sched.internode_msgs(&machine, ppn_for(&machine, s));
            if n != 0 {
                return Err(format!("{}: {n} inter-node msgs from intra-node pattern", s.label()));
            }
        }
        Ok(())
    });
}
