//! End-to-end claims for trace-driven replay (the time axis of the model
//! study):
//!
//! - adaptive replay on the drifting AMR scenario crosses regimes — it
//!   starts on a device-aware strategy, ends on staged Split, and beats
//!   *every* static strategy;
//! - on a stationary trace it exactly matches the best static strategy;
//! - reports are byte-deterministic, and invariant to the message-order
//!   shuffle seed (regime statistics are order-invariant);
//! - surface-driven advice agrees with the exact Table 6 ranking on
//!   on-lattice scenarios;
//! - recorded SpMV traces round-trip through `hetcomm.trace.v1` and replay
//!   as the stationary control;
//! - `sweep --trace` evaluates recorded epochs as sweep cells.

use hetcomm::advisor::{DecisionSurface, SurfaceAxes};
use hetcomm::comm::{StrategyKind, Transport};
use hetcomm::sweep::run_sweep_trace;
use hetcomm::topology::machines;
use hetcomm::trace::persist;
use hetcomm::trace::record;
use hetcomm::trace::replay::{render_report, replay, report_to_json, ReplayConfig, ReplayMode};
use hetcomm::trace::scenarios::{synthesize, TraceScenario};
use hetcomm::Strategy;

fn adaptive() -> ReplayMode<'static> {
    ReplayMode::Adaptive { surface: None }
}

#[test]
fn amr_drift_adaptive_beats_every_static_and_crosses_regimes() {
    let trace = synthesize(TraceScenario::AmrDrift, "lassen", 5, 0, 42).unwrap();
    let r = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();

    // regime crossing: device-aware start, staged node-aware Split finish
    assert_eq!(r.rows.first().unwrap().strategy.transport, Transport::DeviceAware);
    let last = r.rows.last().unwrap().strategy;
    assert_eq!((last.kind, last.transport), (StrategyKind::SplitMd, Transport::Staged));
    assert!(r.switches.len() >= 2, "expected >= 2 switches, got {:?}", r.switches);
    assert!(
        r.switches.iter().any(|s| s.from.transport == Transport::DeviceAware && s.to.transport == Transport::Staged),
        "a device-aware -> staged switch must occur: {:?}",
        r.switches
    );

    // the headline: cumulative modeled time <= every static strategy
    for s in &r.statics {
        assert!(r.total_s <= s.total_s, "adaptive {} loses to {} ({})", r.total_s, s.strategy.label(), s.total_s);
    }
    // and the win over the best static is substantial (measured ~19.6%)
    assert!(r.win_vs_best_static > 0.10, "win vs best static {:.4}", r.win_vs_best_static);
    assert!(r.win_vs_worst_static > 0.40, "win vs worst static {:.4}", r.win_vs_worst_static);
    // every epoch re-advises on this trace (all drifts are large)
    assert!(r.rows.iter().all(|row| row.advised));
    assert_eq!(r.iterations, 15);
}

#[test]
fn stationary_trace_matches_best_static_exactly() {
    let trace = synthesize(TraceScenario::Stationary, "lassen", 4, 0, 42).unwrap();
    let r = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
    assert!(r.switches.is_empty());
    assert_eq!(r.total_s.to_bits(), r.best_static.total_s.to_bits(), "stationary adaptive == best static");
    assert_eq!(r.win_vs_best_static, 0.0);
    // only epoch 0 consults the advisor (zero drift afterwards)
    assert_eq!(r.rows.iter().filter(|row| row.advised).count(), 1);
}

#[test]
fn reports_are_deterministic_and_shuffle_invariant() {
    let run = |seed: u64| {
        let trace = synthesize(TraceScenario::Sparsify, "lassen", 5, 0, seed).unwrap();
        (persist::to_json(&trace), report_to_json(&replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap()))
    };
    let (t1, r1) = run(42);
    let (t2, r2) = run(42);
    assert_eq!(t1, t2, "same seed, same trace bytes");
    assert_eq!(r1, r2, "same seed, same report bytes");
    let (t3, r3) = run(1234);
    assert_ne!(t1, t3, "the seed shuffles message order");
    assert_eq!(r1, r3, "regime statistics are order-invariant, so reports agree across seeds");
}

#[test]
fn surface_advice_matches_exact_ranking_on_lattice_scenarios() {
    let surface = DecisionSurface::compile("lassen", SurfaceAxes::default_axes(), 0.0).unwrap();
    let trace = synthesize(TraceScenario::AmrDrift, "lassen", 5, 0, 42).unwrap();
    let exact = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
    let surf = replay(&trace, &ReplayMode::Adaptive { surface: Some(&surface) }, &ReplayConfig::default()).unwrap();
    assert_eq!(surf.mode, "adaptive:surface");
    for (a, b) in exact.rows.iter().zip(&surf.rows) {
        assert_eq!(a.strategy, b.strategy, "epoch {}: surface pick differs", a.index);
    }
    assert_eq!(exact.total_s.to_bits(), surf.total_s.to_bits());
    // the guarantee carries over: surface-adaptive beats every static too
    for s in &surf.statics {
        assert!(surf.total_s <= s.total_s, "surface-adaptive loses to {}", s.strategy.label());
    }
}

#[test]
fn halo_burst_flips_back_and_forth() {
    let trace = synthesize(TraceScenario::HaloBurst, "lassen", 5, 0, 42).unwrap();
    let r = replay(&trace, &adaptive(), &ReplayConfig::default()).unwrap();
    assert_eq!(r.switches.len(), 4, "each calm<->burst boundary must switch: {:?}", r.switches);
    assert!(r.win_vs_best_static > 0.10, "win {:.4}", r.win_vs_best_static);
    // static replay of the burst-regime winner does strictly worse
    let burst_choice = r.rows[1].strategy;
    let static_run = replay(&trace, &ReplayMode::Static(burst_choice), &ReplayConfig::default()).unwrap();
    assert!(static_run.total_s > r.total_s);
    // the text renderer narrates the switches
    let txt = render_report(&r);
    assert!(txt.matches("switch at epoch").count() == 4, "{txt}");
}

#[test]
fn recorded_spmv_trace_roundtrips_and_replays_as_control() {
    let machine = machines::parse("lassen", 2).unwrap().0;
    let trace = record::record_spmv("thermal2", 2048, 8, &machine, 4, 7).unwrap();
    assert_eq!(trace.epochs.len(), 1, "fixed partition coalesces to one epoch");
    assert_eq!(trace.iterations(), 4);

    // artifact round trip
    let json = persist::to_json(&trace);
    let parsed = persist::parse_json(&json).unwrap();
    assert_eq!(parsed, trace);
    assert_eq!(persist::to_json(&parsed), json);

    // stationary control: adaptive == best static, no switches
    let r = replay(&parsed, &adaptive(), &ReplayConfig::default()).unwrap();
    assert!(r.switches.is_empty());
    assert_eq!(r.total_s.to_bits(), r.best_static.total_s.to_bits());
}

#[test]
fn sweep_consumes_recorded_traces_as_pattern_source() {
    let trace = synthesize(TraceScenario::AmrDrift, "lassen", 5, 0, 42).unwrap();
    let result = run_sweep_trace(&trace, &Strategy::all(), 2, false).unwrap();
    assert_eq!(result.cells.len(), 5 * Strategy::all().len());
    // the per-epoch sweep winners retell the replay story: the winner
    // timeline moves from device-aware to staged Split
    let winners = &result.report.winners;
    assert_eq!(winners.len(), 5);
    assert!(!winners.first().unwrap().winner_staged);
    assert!(winners.last().unwrap().winner_staged);
    assert_eq!(winners.last().unwrap().winner_kind, StrategyKind::SplitMd);
    assert!(!result.report.crossovers.is_empty());
    // cell sizes follow the shrinking AMR messages
    assert_eq!(result.cells.first().unwrap().size, 1 << 18);
    assert_eq!(result.cells.last().unwrap().size, 1 << 10);
}
