//! Property tests over the collective layer: the locality algorithm's
//! inter-node traffic bound, payload conservation across lowered stages,
//! seed determinism of the synthesized patterns, and invariance of every
//! lowering under message-order shuffles.

use hetcomm::collective::{lower, recv_owner, Collective, CollectiveAlgorithm, CollectiveSpec, Lowering};
use hetcomm::pattern::{CommPattern, Msg};
use hetcomm::topology::machines::lassen;
use hetcomm::topology::Machine;
use hetcomm::util::prop::{check, Gen};
use std::collections::BTreeSet;

fn spec_for(g: &mut Gen) -> (Collective, usize, u64) {
    let c = *g.choose(&Collective::ALL);
    let block = g.usize(1, 1 << 14);
    let seed = g.u64(1 << 40);
    (c, block, seed)
}

/// Unique inter-node bytes of a pattern: duplicate payloads (`dup_group`)
/// count once per (source, destination node) — the minimum any node-aware
/// lowering must ship.
fn unique_internode(m: &Machine, p: &CommPattern) -> usize {
    let mut seen = BTreeSet::new();
    let mut total = 0;
    for x in p.internode(m) {
        if x.dup_group == Msg::NO_DUP || seen.insert((x.src, x.dup_group, m.gpu_node(x.dst))) {
            total += x.bytes;
        }
    }
    total
}

#[test]
fn locality_never_ships_more_internode_traffic_than_standard() {
    check("locality inter-node msgs/bytes <= standard", 60, |g| {
        let m = lassen(g.usize(2, 6));
        let (c, block, seed) = spec_for(g);
        let direct = CollectiveSpec::new(c, block, seed).materialize(&m);
        let std_l = lower(c, CollectiveAlgorithm::Standard, &m, &direct);
        let pw_l = lower(c, CollectiveAlgorithm::Pairwise, &m, &direct);
        let loc_l = lower(c, CollectiveAlgorithm::Locality, &m, &direct);
        if loc_l.internode_msgs(&m) > std_l.internode_msgs(&m) {
            return Err(format!(
                "{c}: locality issues {} inter-node msgs, standard {}",
                loc_l.internode_msgs(&m),
                std_l.internode_msgs(&m)
            ));
        }
        if loc_l.internode_bytes(&m) > std_l.internode_bytes(&m) {
            return Err(format!(
                "{c}: locality ships {} inter-node bytes, standard {}",
                loc_l.internode_bytes(&m),
                std_l.internode_bytes(&m)
            ));
        }
        // pairwise only reorders: the network sees the same messages
        if pw_l.internode_msgs(&m) != std_l.internode_msgs(&m)
            || pw_l.internode_bytes(&m) != std_l.internode_bytes(&m)
        {
            return Err(format!("{c}: pairwise changed the inter-node traffic"));
        }
        Ok(())
    });
}

#[test]
fn lowered_stages_conserve_payload() {
    check("stage byte totals conserve the collective payload", 60, |g| {
        let m = lassen(g.usize(2, 6));
        let (c, block, seed) = spec_for(g);
        let direct = CollectiveSpec::new(c, block, seed).materialize(&m);
        let direct_inter: usize = direct.internode(&m).map(|x| x.bytes).sum();

        // pairwise partitions the direct pattern exactly
        let pw = lower(c, CollectiveAlgorithm::Pairwise, &m, &direct);
        let pw_total: usize = pw.stages.iter().map(|s| s.pattern.total_bytes()).sum();
        let pw_msgs: usize = pw.stages.iter().map(|s| s.pattern.msgs.len()).sum();
        if pw_total != direct.total_bytes() || pw_msgs != direct.msgs.len() {
            return Err(format!("{c}: pairwise rounds do not partition the pattern"));
        }

        // locality ships each unique payload across the network exactly once
        let loc = lower(c, CollectiveAlgorithm::Locality, &m, &direct);
        if loc.internode_bytes(&m) != unique_internode(&m, &direct) {
            return Err(format!(
                "{c}: locality ships {} inter-node bytes, unique payload is {}",
                loc.internode_bytes(&m),
                unique_internode(&m, &direct)
            ));
        }
        // ...and the redistribute stage restores every per-destination
        // payload that does not already land on its final process
        let redist: usize =
            loc.stages.iter().filter(|s| s.label == "redistribute").map(|s| s.pattern.total_bytes()).sum();
        let kept: usize = direct
            .internode(&m)
            .filter(|x| x.dst == recv_owner(&m, m.gpu_node(x.src), m.gpu_node(x.dst)))
            .map(|x| x.bytes)
            .sum();
        if redist + kept != direct_inter {
            return Err(format!(
                "{c}: redistribute {redist} + kept {kept} != direct inter-node {direct_inter}"
            ));
        }
        Ok(())
    });
}

#[test]
fn materialization_is_seed_deterministic() {
    check("same spec same pattern; alltoallv follows the seed", 40, |g| {
        let m = lassen(g.usize(2, 5));
        let (c, block, seed) = spec_for(g);
        let a = CollectiveSpec::new(c, block, seed).materialize(&m);
        let b = CollectiveSpec::new(c, block, seed).materialize(&m);
        if a != b {
            return Err(format!("{c}: same spec produced different patterns"));
        }
        // alltoallv's irregular counts must actually follow the seed (tiny
        // blocks collapse the per-pair size range to one value; skip those)
        if c == Collective::Alltoallv && block >= 8 {
            let other = CollectiveSpec::new(c, block, seed ^ 0x9e37_79b9).materialize(&m);
            if a == other {
                return Err("alltoallv ignored the seed".into());
            }
        }
        Ok(())
    });
}

#[test]
fn lowering_is_invariant_under_message_shuffles() {
    check("lowering ignores message enumeration order", 40, |g| {
        let m = lassen(g.usize(2, 5));
        let (c, block, seed) = spec_for(g);
        let direct = CollectiveSpec::new(c, block, seed).materialize(&m);
        let mut shuffled = direct.clone();
        g.rng().shuffle(&mut shuffled.msgs);
        for alg in CollectiveAlgorithm::ALL {
            let a = lower(c, alg, &m, &direct);
            let b = lower(c, alg, &m, &shuffled);
            // standard/pairwise keep enumeration order inside a stage;
            // compare per-stage multisets
            let key = |l: &Lowering| -> Vec<Vec<(usize, usize, usize, u32)>> {
                l.stages
                    .iter()
                    .map(|s| {
                        let mut v: Vec<(usize, usize, usize, u32)> =
                            s.pattern.msgs.iter().map(|x| (x.src.0, x.dst.0, x.bytes, x.dup_group)).collect();
                        v.sort_unstable();
                        v
                    })
                    .collect()
            };
            if key(&a) != key(&b) {
                return Err(format!("{c} {alg}: lowering depends on message order"));
            }
            // the locality lowering is canonical (ordered-map aggregation):
            // not just the same multiset, the same bytes
            if alg == CollectiveAlgorithm::Locality && a != b {
                return Err(format!("{c}: locality lowering is not canonical"));
            }
        }
        Ok(())
    });
}
