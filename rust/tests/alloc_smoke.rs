//! Allocation-free smoke test for the simulator's inner loop.
//!
//! Installs a counting global allocator and asserts that, once the
//! per-worker scratch has warmed up, re-lowering and re-executing a
//! schedule performs **zero** heap allocations — the acceptance criterion
//! of the compiled hot path. This lives in its own integration-test binary
//! (single `#[test]`) so no concurrently-running test can touch the
//! allocation counter.

use hetcomm::comm::{build_schedule, Strategy};
use hetcomm::params::lassen_params;
use hetcomm::pattern::generators::random_pattern;
use hetcomm::sim;
use hetcomm::topology::machines::lassen;
use hetcomm::util::rng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

struct CountingAllocator;

// SAFETY: delegates every operation to `System`; only adds a relaxed
// counter bump on allocation paths.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

#[test]
fn inner_sim_loop_is_allocation_free_after_warmup() {
    let machine = lassen(4);
    let params = lassen_params();
    let compiled_params = params.compile();
    let mut rng = Rng::new(99);
    let pattern = random_pattern(&machine, &mut rng, 128, 1 << 16, 0.25);
    let schedules: Vec<_> = Strategy::all()
        .into_iter()
        .map(|s| (build_schedule(s, &machine, &pattern), s.sim_ppn(&machine)))
        .collect();

    let mut scratch = sim::Scratch::new();
    // Warm-up: grows the scratch arrays to this machine's resource count
    // and the largest schedule's op counts.
    let warm: Vec<f64> =
        schedules.iter().map(|(sched, ppn)| scratch.run_total(&machine, &compiled_params, sched, *ppn)).collect();

    let before = ALLOCATIONS.load(Ordering::Relaxed);
    let mut totals = Vec::with_capacity(schedules.len()); // allocated before the measured region
    for _ in 0..10 {
        totals.clear();
        for (sched, ppn) in &schedules {
            totals.push(scratch.run_total(&machine, &compiled_params, sched, *ppn));
        }
    }
    let after = ALLOCATIONS.load(Ordering::Relaxed);

    assert_eq!(
        after - before,
        0,
        "lower_into + run_compiled allocated {} times after warm-up",
        after - before
    );
    // and the warm runs reproduced the warm-up answers bit for bit
    for (w, t) in warm.iter().zip(&totals) {
        assert_eq!(w.to_bits(), t.to_bits());
    }
}
