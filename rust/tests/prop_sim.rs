//! Property tests for the compiled simulation hot path: the flat
//! zero-allocation executor must match the retained reference
//! implementation **bit for bit** on randomized schedules — every strategy,
//! both transports, machines from 1 to 16 nodes, with and without
//! duplicate data.
//!
//! (The companion allocation-free smoke assertion lives in
//! `tests/alloc_smoke.rs`, its own binary, because it installs a counting
//! global allocator that must not race other tests' allocations.)

use hetcomm::comm::{build_schedule, build_schedule_from, CopyKind, CopyOp, Loc, Phase, Schedule, Strategy, Xfer};
use hetcomm::params::lassen_params;
use hetcomm::pattern::generators::random_pattern;
use hetcomm::sim::{self, CompiledPattern};
use hetcomm::topology::machines::lassen;
use hetcomm::topology::{GpuId, ProcId};
use hetcomm::util::prop::{check, Gen};

fn assert_bit_equal(fast: &sim::SimReport, slow: &sim::SimReport, context: &str) -> Result<(), String> {
    if fast.total.to_bits() != slow.total.to_bits() {
        return Err(format!("{context}: total {:e} != reference {:e}", fast.total, slow.total));
    }
    if fast.max_node_injected != slow.max_node_injected {
        return Err(format!(
            "{context}: injected {} != reference {}",
            fast.max_node_injected, slow.max_node_injected
        ));
    }
    if fast.internode_msgs != slow.internode_msgs {
        return Err(format!("{context}: msgs {} != reference {}", fast.internode_msgs, slow.internode_msgs));
    }
    if fast.phase_times.len() != slow.phase_times.len() {
        return Err(format!("{context}: phase count mismatch"));
    }
    for (a, b) in fast.phase_times.iter().zip(&slow.phase_times) {
        if a.0 != b.0 || a.1.to_bits() != b.1.to_bits() {
            return Err(format!("{context}: phase {:?} {:e} != {:?} {:e}", a.0, a.1, b.0, b.1));
        }
    }
    Ok(())
}

#[test]
fn compiled_executor_matches_reference_on_strategy_schedules() {
    check("compiled == reference on all Table 5 schedules", 40, |g| {
        let machine = lassen(g.usize(1, 17)); // 1..=16 nodes
        let n_msgs = g.usize(1, 64);
        let max_size = 1usize << g.usize(4, 19);
        let dup = if g.bool(0.5) { 0.3 } else { 0.0 };
        let pattern = random_pattern(&machine, g.rng(), n_msgs, max_size, dup);
        let params = lassen_params();
        let lowered = CompiledPattern::lower(&machine, &pattern);
        let compiled_params = params.compile();
        let mut scratch = sim::Scratch::new();
        for s in Strategy::all() {
            let ppn = s.sim_ppn(&machine);
            // the one-lowering-per-cell build must equal the wrapper build
            let schedule = build_schedule_from(s, &machine, &lowered);
            let rebuilt = build_schedule(s, &machine, &pattern);
            if schedule != rebuilt {
                return Err(format!("{}: build_schedule_from != build_schedule", s.label()));
            }
            let fast = scratch.run_report(&machine, &compiled_params, &schedule, ppn);
            let slow = sim::run_reference(&machine, &params, &schedule, ppn);
            assert_bit_equal(&fast, &slow, s.label())?;
            // and the convenience wrapper routes through the same compiled path
            let wrapped = sim::run(&machine, &params, &schedule, ppn);
            assert_bit_equal(&wrapped, &slow, s.label())?;
        }
        Ok(())
    });
}

#[test]
fn compiled_executor_matches_reference_on_raw_schedules() {
    // Not just builder output: arbitrary phase structures with hand-rolled
    // transfers and copies (mixed endpoints, zero-byte ops, repeated
    // resources) must agree too.
    check("compiled == reference on raw schedules", 60, |g| {
        let nodes = g.usize(1, 17);
        let machine = lassen(nodes);
        let ppn = *g.choose(&[1usize, 2, 4, 8, 40]);
        let ppn = ppn.min(machine.cores_per_node());
        let n_procs = machine.num_nodes * ppn;
        let n_gpus = machine.total_gpus();
        let n_phases = g.usize(1, 5);
        let mut phases = Vec::new();
        for pi in 0..n_phases {
            let mut phase = Phase::new(["a", "b", "c", "d"][pi % 4]);
            for t in 0..g.usize(0, 24) {
                let loc = |g: &mut Gen| {
                    if g.bool(0.5) {
                        Loc::Host(ProcId(g.usize(0, n_procs)))
                    } else {
                        Loc::Gpu(GpuId(g.usize(0, n_gpus)))
                    }
                };
                let bytes = if g.bool(0.1) { 0 } else { g.msg_size() };
                phase.xfers.push(Xfer { src: loc(g), dst: loc(g), bytes, tag: t as u32 });
            }
            for _ in 0..g.usize(0, 6) {
                phase.copies.push(CopyOp {
                    gpu: GpuId(g.usize(0, n_gpus)),
                    proc: ProcId(g.usize(0, n_procs)),
                    bytes: g.msg_size(),
                    dir: if g.bool(0.5) { CopyKind::D2H } else { CopyKind::H2D },
                    nprocs: *g.choose(&[1usize, 4]),
                });
            }
            phases.push(phase);
        }
        let schedule = Schedule { strategy_label: "raw".into(), phases };
        let params = lassen_params();
        let fast = sim::run(&machine, &params, &schedule, ppn);
        let slow = sim::run_reference(&machine, &params, &schedule, ppn);
        assert_bit_equal(&fast, &slow, "raw schedule")
    });
}

#[test]
fn scratch_reuse_never_leaks_state_between_schedules() {
    // One scratch driven across many different (machine, schedule, ppn)
    // triples must reproduce the fresh-scratch answer every time.
    check("scratch reuse is stateless", 20, |g| {
        let params = lassen_params();
        let compiled_params = params.compile();
        let mut scratch = sim::Scratch::new();
        for _ in 0..6 {
            let machine = lassen(g.usize(1, 9));
            let pattern = random_pattern(&machine, g.rng(), g.usize(1, 40), 1 << 14, 0.2);
            let s = *g.choose(&Strategy::all());
            let schedule = build_schedule(s, &machine, &pattern);
            let ppn = s.sim_ppn(&machine);
            let reused = scratch.run_total(&machine, &compiled_params, &schedule, ppn);
            let fresh = sim::Scratch::new().run_total(&machine, &compiled_params, &schedule, ppn);
            if reused.to_bits() != fresh.to_bits() {
                return Err(format!("{}: reused {reused:e} != fresh {fresh:e}", s.label()));
            }
        }
        Ok(())
    });
}

#[test]
fn compiled_params_match_branching_params_everywhere() {
    use hetcomm::params::{CopyDir, Endpoint};
    use hetcomm::topology::Locality;
    check("band tables == protocol branching", 200, |g| {
        let params = lassen_params();
        let compiled = params.compile();
        let s = g.msg_size();
        for ep in [Endpoint::Cpu, Endpoint::Gpu] {
            for l in [Locality::OnSocket, Locality::OnNode, Locality::OffNode] {
                let a = compiled.msg_time(ep, l, s);
                let b = params.msg_time(ep, l, s);
                if a.to_bits() != b.to_bits() {
                    return Err(format!("{ep:?} {l} {s}: {a:e} != {b:e}"));
                }
            }
        }
        let np = *g.choose(&[1usize, 2, 3, 4]);
        for dir in [CopyDir::H2D, CopyDir::D2H] {
            let a = compiled.memcpy_time(dir, s, np);
            let b = params.memcpy_time(dir, s, np);
            if a.to_bits() != b.to_bits() {
                return Err(format!("memcpy {dir:?} {s} x{np}: {a:e} != {b:e}"));
            }
        }
        Ok(())
    });
}
