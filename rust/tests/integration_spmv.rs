//! Integration: the full distributed-SpMV data plane — every strategy, on
//! several matrix structures and partition counts, verified bit-for-bit
//! against the serial CSR oracle; plus failure injection.

use hetcomm::comm::{Strategy, StrategyKind, Transport};
use hetcomm::coordinator::{DistSpmv, SpmvConfig};
use hetcomm::sparse::{gen, suite};
use hetcomm::topology::machines::{delta_like, frontier_like, lassen};
use hetcomm::util::prop::check;
use hetcomm::util::rng::Rng;

fn staged_strategies() -> Vec<Strategy> {
    StrategyKind::ALL.iter().map(|&k| Strategy::new(k, Transport::Staged).unwrap()).collect()
}

fn random_v(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect()
}

#[test]
fn all_strategies_all_matrices_verify() {
    let machine = lassen(2);
    let mut rng = Rng::new(1);
    let matrices = vec![
        ("stencil5", gen::stencil_5pt(20, 20)),
        ("stencil27", gen::stencil_27pt(8, 8, 8)),
        ("banded", gen::banded(400, 6, &mut rng)),
        ("arrow", gen::arrow(400, 12, 3, &mut rng)),
    ];
    for (name, a) in matrices {
        let v = random_v(a.nrows, 17);
        for s in staged_strategies() {
            let d = DistSpmv::new(&a, 8, &machine, s, SpmvConfig::default()).unwrap();
            let rep = d.run(&v, 1).unwrap();
            assert_eq!(rep.verified, Some(true), "{name}/{}: max err {}", s.label(), rep.max_abs_err);
        }
    }
}

#[test]
fn partition_counts_sweep() {
    let a = gen::stencil_27pt(6, 6, 8);
    let v = random_v(a.nrows, 23);
    for nparts in [1usize, 2, 3, 4, 5, 8] {
        let machine = lassen(2);
        let s = Strategy::new(StrategyKind::ThreeStep, Transport::Staged).unwrap();
        let d = DistSpmv::new(&a, nparts, &machine, s, SpmvConfig::default()).unwrap();
        let rep = d.run(&v, 1).unwrap();
        assert_eq!(rep.verified, Some(true), "nparts={nparts}: max err {}", rep.max_abs_err);
    }
}

#[test]
fn future_machines_also_verify() {
    // Section 6: the strategies extend to single-socket high-core-count
    // nodes (Frontier-like) and wide Delta-like nodes.
    let a = gen::stencil_27pt(6, 6, 6);
    let v = random_v(a.nrows, 29);
    for machine in [frontier_like(2), delta_like(2)] {
        for s in staged_strategies() {
            let d = DistSpmv::new(&a, machine.gpus_per_node(), &machine, s, SpmvConfig::default()).unwrap();
            let rep = d.run(&v, 1).unwrap();
            assert_eq!(rep.verified, Some(true), "{}/{}", machine.name, s.label());
        }
    }
}

#[test]
fn suite_proxies_verify_on_split_md() {
    let machine = lassen(2);
    for info in &suite::MATRICES {
        let a = suite::proxy(info, 256); // small proxies for test speed
        let v = random_v(a.nrows, 31);
        let s = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();
        let d = DistSpmv::new(&a, 8, &machine, s, SpmvConfig::default()).unwrap();
        let rep = d.run(&v, 1).unwrap();
        assert_eq!(rep.verified, Some(true), "{}: max err {}", info.name, rep.max_abs_err);
    }
}

#[test]
fn small_caps_stress_split_routing() {
    let a = gen::stencil_27pt(8, 8, 4);
    let machine = lassen(2);
    let v = random_v(a.nrows, 37);
    for cap in [16usize, 64, 512, 8192] {
        for kind in [StrategyKind::SplitMd, StrategyKind::SplitDd] {
            let s = Strategy::new(kind, Transport::Staged).unwrap().with_cap(cap);
            let d = DistSpmv::new(&a, 8, &machine, s, SpmvConfig::default()).unwrap();
            let rep = d.run(&v, 1).unwrap();
            assert_eq!(rep.verified, Some(true), "{kind:?} cap {cap}: err {}", rep.max_abs_err);
        }
    }
}

#[test]
fn iterations_deterministic() {
    let a = gen::stencil_5pt(12, 12);
    let machine = lassen(1);
    let v = random_v(a.nrows, 41);
    let s = Strategy::new(StrategyKind::TwoStep, Transport::Staged).unwrap();
    let d = DistSpmv::new(&a, 4, &machine, s, SpmvConfig::default()).unwrap();
    let r1 = d.run(&v, 1).unwrap();
    let r2 = d.run(&v, 4).unwrap();
    assert_eq!(r1.w, r2.w);
}

#[test]
fn random_patterns_property() {
    check("random banded matrices verify under random strategies", 6, |g| {
        let n = g.usize(64, 300);
        let band = g.usize(1, 8);
        let mut rng = Rng::new(g.case_seed);
        let a = gen::banded(n, band, &mut rng);
        let nparts = *g.choose(&[2usize, 4, 8]);
        let machine = lassen(2);
        let kind = *g.choose(&StrategyKind::ALL);
        let s = Strategy::new(kind, Transport::Staged).unwrap();
        let v = random_v(a.nrows, g.case_seed);
        let d = DistSpmv::new(&a, nparts, &machine, s, SpmvConfig::default())
            .map_err(|e| format!("setup: {e}"))?;
        let rep = d.run(&v, 1).map_err(|e| format!("run: {e}"))?;
        if rep.verified != Some(true) {
            return Err(format!("{kind:?} nparts {nparts}: max err {}", rep.max_abs_err));
        }
        Ok(())
    });
}

// ---- failure injection ------------------------------------------------

#[test]
fn wrong_vector_length_rejected() {
    let a = gen::stencil_5pt(8, 8);
    let machine = lassen(1);
    let s = Strategy::new(StrategyKind::Standard, Transport::Staged).unwrap();
    let d = DistSpmv::new(&a, 4, &machine, s, SpmvConfig::default()).unwrap();
    assert!(d.run(&vec![1.0; 63], 1).is_err());
    assert!(d.run(&vec![1.0; 65], 1).is_err());
}

#[test]
fn zero_iterations_rejected() {
    let a = gen::stencil_5pt(8, 8);
    let machine = lassen(1);
    let s = Strategy::new(StrategyKind::Standard, Transport::Staged).unwrap();
    let d = DistSpmv::new(&a, 4, &machine, s, SpmvConfig::default()).unwrap();
    assert!(d.run(&vec![1.0; 64], 0).is_err());
}

#[test]
fn oversubscribed_machine_rejected() {
    let a = gen::stencil_5pt(8, 8);
    let machine = lassen(1); // 4 GPUs
    let s = Strategy::new(StrategyKind::Standard, Transport::Staged).unwrap();
    assert!(DistSpmv::new(&a, 5, &machine, s, SpmvConfig::default()).is_err());
}

#[test]
fn device_aware_split_rejected_at_construction() {
    assert!(Strategy::new(StrategyKind::SplitMd, Transport::DeviceAware).is_err());
    assert!(Strategy::new(StrategyKind::SplitDd, Transport::DeviceAware).is_err());
}

#[test]
fn power_iteration_on_zero_matrix_fails_cleanly() {
    let a = hetcomm::sparse::csr::Csr::from_triplets(16, 16, &[(0, 0, 0.0)]);
    let machine = lassen(1);
    let s = Strategy::new(StrategyKind::Standard, Transport::Staged).unwrap();
    let d = DistSpmv::new(&a, 4, &machine, s, SpmvConfig::default()).unwrap();
    let err = d.power_iterate(&vec![1.0; 16], 3).unwrap_err();
    assert!(err.to_string().contains("collapsed"), "{err}");
}
