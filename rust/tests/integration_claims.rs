//! Integration: the paper's qualitative claims, checked end-to-end against
//! the models and the simulator — the assertions behind Figures 2.5, 2.6,
//! 4.2, 4.3 and 5.1.

use hetcomm::comm::{build_schedule, Strategy, StrategyKind, Transport};
use hetcomm::model::StrategyModel;
use hetcomm::params::{lassen_params, Endpoint};
use hetcomm::pattern::generators::Scenario;
use hetcomm::sim;
use hetcomm::sim::network::{nodepong, pingpong};
use hetcomm::sparse::{suite, PartitionedMatrix};
use hetcomm::topology::machines::lassen;
use hetcomm::topology::Locality;

fn ppn_for(machine: &hetcomm::topology::Machine, s: Strategy) -> usize {
    match s.kind {
        StrategyKind::SplitMd | StrategyKind::SplitDd => machine.cores_per_node(),
        _ => machine.gpus_per_node() * s.kind.ppg(),
    }
}

/// Figure 2.5: small messages order on-socket < on-node < off-node; at
/// 1 MiB the network beats the cross-socket path.
#[test]
fn fig25_locality_orderings() {
    let p = lassen_params();
    for s in [64usize, 512, 4096] {
        let a = pingpong(&p, Endpoint::Cpu, Locality::OnSocket, s);
        let b = pingpong(&p, Endpoint::Cpu, Locality::OnNode, s);
        let c = pingpong(&p, Endpoint::Cpu, Locality::OffNode, s);
        assert!(a < b && b < c, "size {s}: {a} {b} {c}");
    }
    let big = 1 << 20;
    assert!(
        pingpong(&p, Endpoint::Cpu, Locality::OffNode, big) < pingpong(&p, Endpoint::Cpu, Locality::OnNode, big)
    );
}

/// Figure 2.6: the optimal ppn grows with volume.
#[test]
fn fig26_optimal_ppn_grows() {
    let m = lassen(2);
    let p = lassen_params();
    let choices = [1usize, 2, 4, 8, 16, 32, 40];
    let mut last_best = 1;
    for e in [10usize, 14, 18, 22] {
        let best = sim::network::best_ppn(&m, &p, 1 << e, &choices);
        assert!(best >= last_best, "best ppn shrank: {best} < {last_best} at 2^{e}");
        last_best = best;
    }
    assert!(last_best > 1, "large volumes must favor splitting");
    // sanity: nodepong at the winning ppn actually beats ppn=1
    assert!(nodepong(&m, &p, 1 << 22, last_best) < nodepong(&m, &p, 1 << 22, 1));
}

/// Figure 4.3 (high message count): staged node-aware beats standard and
/// all device-aware strategies for message sizes up to ~10^4 B, and 3-Step
/// device-aware beats standard device-aware.
#[test]
fn fig43_staged_nodeaware_wins_high_message_count() {
    let machine = lassen(32);
    let params = lassen_params();
    let sm = StrategyModel::new(&machine, &params);
    for n_dest in [4usize, 16] {
        for size in [256usize, 1024, 4096] {
            let sc = Scenario { n_msgs: 256, msg_size: size, n_dest, dup_frac: 0.0 };
            let inputs = sc.inputs(&machine, machine.cores_per_node());
            let best_staged_na = [StrategyKind::ThreeStep, StrategyKind::TwoStep, StrategyKind::SplitMd]
                .iter()
                .map(|&k| sm.time(Strategy::new(k, Transport::Staged).unwrap(), &inputs))
                .fold(f64::INFINITY, f64::min);
            let std_da = sm.time(Strategy::new(StrategyKind::Standard, Transport::DeviceAware).unwrap(), &inputs);
            let three_da = sm.time(Strategy::new(StrategyKind::ThreeStep, Transport::DeviceAware).unwrap(), &inputs);
            assert!(
                best_staged_na < std_da,
                "dest {n_dest} size {size}: staged NA {best_staged_na} !< standard DA {std_da}"
            );
            assert!(three_da < std_da, "dest {n_dest} size {size}: 3-step DA {three_da} !< std DA {std_da}");
        }
    }
}

/// Figure 4.3b: Split+MD is the fastest staged strategy at 16 destination
/// nodes and moderate sizes.
#[test]
fn fig43b_split_md_wins_16_nodes() {
    let machine = lassen(32);
    let params = lassen_params();
    let sm = StrategyModel::new(&machine, &params);
    let sc = Scenario { n_msgs: 256, msg_size: 1024, n_dest: 16, dup_frac: 0.0 };
    let inputs = sc.inputs(&machine, machine.cores_per_node());
    let split = sm.time(Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap(), &inputs);
    for k in [StrategyKind::Standard, StrategyKind::ThreeStep, StrategyKind::TwoStep] {
        let other = sm.time(Strategy::new(k, Transport::Staged).unwrap(), &inputs);
        assert!(split < other, "Split+MD {split} !< {k:?} {other}");
    }
}

/// Figure 4.3: device-aware standard wins only at very large message
/// sizes.
#[test]
fn fig43_device_aware_wins_extreme_sizes() {
    let machine = lassen(32);
    let params = lassen_params();
    let sm = StrategyModel::new(&machine, &params);
    // Small count, few nodes, 1 MiB messages: the DA path's single hop
    // with no staging wins.
    let sc = Scenario { n_msgs: 32, msg_size: 1 << 20, n_dest: 4, dup_frac: 0.0 };
    let inputs = sc.inputs(&machine, machine.cores_per_node());
    let (best, _) = sm.best(&inputs);
    assert_eq!(best.transport, Transport::DeviceAware, "best at 1 MiB was {}", best.label());
}

/// Section 4.6 / Figure 4.3 bottom rows: removing 25% duplicates speeds
/// node-aware strategies, leaves standard untouched.
#[test]
fn dedup_only_affects_node_aware() {
    let machine = lassen(32);
    let params = lassen_params();
    let sm = StrategyModel::new(&machine, &params);
    let base = Scenario { n_msgs: 256, msg_size: 4096, n_dest: 16, dup_frac: 0.0 };
    let dedup = Scenario { dup_frac: 0.25, ..base };
    let bi = base.inputs(&machine, machine.cores_per_node());
    let di = dedup.inputs(&machine, machine.cores_per_node());
    for s in Strategy::all() {
        let t0 = sm.time(s, &bi);
        let t1 = sm.time(s, &di);
        if s.kind == StrategyKind::Standard {
            assert_eq!(t0, t1, "{}", s.label());
        } else {
            assert!(t1 < t0, "{}: dedup didn't help ({t1} !< {t0})", s.label());
        }
    }
}

/// Figure 5.1: across the SuiteSparse set, a staged strategy is fastest in
/// the (simulated) benchmark for the large-GPU-count cells, and Split+MD
/// wins the majority.
#[test]
fn fig51_staged_split_dominates_suite() {
    let params = lassen_params();
    let mut split_wins = 0usize;
    let mut staged_wins = 0usize;
    let mut cells = 0usize;
    for info in &suite::MATRICES {
        let mat = suite::proxy(info, 128);
        let gpus = 32;
        if gpus * 8 > mat.nrows {
            continue;
        }
        let machine = lassen(8);
        let pm = PartitionedMatrix::build(&mat, gpus);
        let pattern = pm.comm_pattern(&machine, 8);
        let mut best: Option<(Strategy, f64)> = None;
        for s in Strategy::all() {
            let sched = build_schedule(s, &machine, &pattern);
            let t = sim::run(&machine, &params, &sched, ppn_for(&machine, s)).total;
            if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                best = Some((s, t));
            }
        }
        let (winner, _) = best.unwrap();
        cells += 1;
        if winner.transport == Transport::Staged {
            staged_wins += 1;
        }
        if winner.kind == StrategyKind::SplitMd {
            split_wins += 1;
        }
    }
    assert!(cells >= 4, "not enough cells ({cells})");
    assert!(staged_wins * 10 >= cells * 8, "staged won only {staged_wins}/{cells}");
    assert!(split_wins * 2 >= cells, "Split+MD won only {split_wins}/{cells}");
}

/// Section 5.1: Split+DD never beats Split+MD in the benchmark cells.
#[test]
fn split_dd_worse_than_md() {
    let params = lassen_params();
    for info in suite::MATRICES.iter().take(3) {
        let mat = suite::proxy(info, 128);
        let machine = lassen(8);
        let pm = PartitionedMatrix::build(&mat, 32.min(mat.nrows / 8));
        let pattern = pm.comm_pattern(&machine, 8);
        let md = Strategy::new(StrategyKind::SplitMd, Transport::Staged).unwrap();
        let dd = Strategy::new(StrategyKind::SplitDd, Transport::Staged).unwrap();
        let t_md = sim::run(&machine, &params, &build_schedule(md, &machine, &pattern), machine.cores_per_node()).total;
        let t_dd = sim::run(&machine, &params, &build_schedule(dd, &machine, &pattern), machine.cores_per_node()).total;
        assert!(t_md <= t_dd * 1.05, "{}: MD {t_md} vs DD {t_dd}", info.name);
    }
}

/// Device-aware node-aware (3-step/2-step) beats device-aware standard on
/// the SpMV patterns (Section 5.1).
#[test]
fn da_nodeaware_beats_da_standard_on_spmv() {
    let params = lassen_params();
    let info = suite::info("audikw_1").unwrap();
    let mat = suite::proxy(info, 128);
    let machine = lassen(8);
    let pm = PartitionedMatrix::build(&mat, 32);
    let pattern = pm.comm_pattern(&machine, 8);
    let t = |k| {
        let s = Strategy::new(k, Transport::DeviceAware).unwrap();
        sim::run(&machine, &params, &build_schedule(s, &machine, &pattern), 4).total
    };
    let std_da = t(StrategyKind::Standard);
    let three_da = t(StrategyKind::ThreeStep);
    assert!(three_da < std_da, "3-step DA {three_da} !< standard DA {std_da}");
}
