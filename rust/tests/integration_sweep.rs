//! Integration: the parallel strategy-sweep engine end to end —
//! (a) fixed seed → byte-identical JSON output, independent of thread
//! count; (b) the high-node-count regime selects a staged node-aware Split
//! strategy, matching the Table 6 model ordering (Figure 4.3b).

use hetcomm::comm::{Strategy, StrategyKind, Transport};
use hetcomm::sweep::{emit, run_sweep, GridSpec, PatternGen, SweepConfig};

fn paper_grid() -> GridSpec {
    GridSpec {
        gens: vec![PatternGen::Uniform],
        dest_nodes: vec![4, 16],
        gpus_per_node: vec![4],
        nics: vec![1],
        sizes: vec![16, 256, 1024, 4096, 1 << 18],
        n_msgs: 256,
        dup_frac: 0.0,
    }
}

#[test]
fn fixed_seed_json_byte_identical() {
    let config = SweepConfig {
        grid: GridSpec {
            gens: vec![PatternGen::Uniform, PatternGen::Random],
            dest_nodes: vec![4, 16],
            gpus_per_node: vec![4],
            nics: vec![1],
            sizes: vec![256, 4096],
            n_msgs: 128,
            dup_frac: 0.1,
        },
        seed: 7,
        threads: 3,
        sim: true,
        ..Default::default()
    };
    let a = emit::to_json(&run_sweep(&config).unwrap());
    let b = emit::to_json(&run_sweep(&config).unwrap());
    assert_eq!(a, b, "same seed must reproduce byte-identical JSON");
    assert!(a.contains("\"sim_s\": ") && !a.contains("\"sim_s\": null"), "sim must have run");
}

#[test]
fn thread_count_does_not_change_json() {
    let mk = |threads: usize| SweepConfig {
        grid: paper_grid(),
        seed: 9,
        threads,
        sim: true,
        ..Default::default()
    };
    let serial = emit::to_json(&run_sweep(&mk(1)).unwrap());
    let parallel = emit::to_json(&run_sweep(&mk(4)).unwrap());
    assert_eq!(serial, parallel, "thread count must not leak into results");
}

#[test]
fn high_node_count_regime_selects_node_aware_split() {
    // Figure 4.3b / Table 6: with 256 inter-node messages to 16 destination
    // nodes, the staged Split strategies win the small/moderate-size band.
    let config = SweepConfig { grid: paper_grid(), sim: false, ..Default::default() };
    let result = run_sweep(&config).unwrap();

    let regime = result
        .report
        .regimes
        .iter()
        .find(|g| g.dest_nodes == 16 && g.band == "small")
        .expect("high-node-count small-band regime present");
    assert!(
        matches!(regime.winner_kind, StrategyKind::SplitMd | StrategyKind::SplitDd),
        "expected a Split strategy to win the high-node-count regime, got {}",
        regime.winner
    );
    assert!(regime.winner_staged, "Split strategies are staged-through-host only");

    // Table 6 ordering at (256 msgs, 16 nodes, 1 KiB): Split+MD (staged)
    // beats every other strategy — staged node-aware, device-aware, and
    // standard communication alike.
    let cell_1k: Vec<_> =
        result.cells.iter().filter(|c| c.dest_nodes == 16 && c.size == 1024).collect();
    assert_eq!(cell_1k.len(), Strategy::all().len());
    let split_md = cell_1k
        .iter()
        .find(|c| c.strategy.kind == StrategyKind::SplitMd)
        .expect("Split+MD evaluated");
    for c in &cell_1k {
        if c.strategy.kind != StrategyKind::SplitMd {
            assert!(
                split_md.model_s < c.model_s,
                "Split+MD {} must beat {} {} at 1 KiB x 16 nodes",
                split_md.model_s,
                c.label,
                c.model_s
            );
        }
    }
    // ...and specifically beats the best device-aware option, the paper's
    // staged-vs-device-aware headline.
    let best_da = cell_1k
        .iter()
        .filter(|c| c.strategy.transport == Transport::DeviceAware)
        .map(|c| c.model_s)
        .fold(f64::INFINITY, f64::min);
    assert!(split_md.model_s < best_da, "staged Split+MD {} !< best device-aware {}", split_md.model_s, best_da);
}

#[test]
fn crossover_from_staged_split_to_device_aware() {
    // Along the 16-destination line the model winner flips from a staged
    // Split strategy (moderate sizes) to device-aware communication
    // (large sizes) — the crossover the paper locates near 10^4 B.
    let config = SweepConfig { grid: paper_grid(), sim: false, ..Default::default() };
    let result = run_sweep(&config).unwrap();

    let line: Vec<_> = result.report.crossovers.iter().filter(|x| x.dest_nodes == 16).collect();
    assert!(!line.is_empty(), "expected at least one crossover on the 16-node line");
    assert!(
        line.iter().any(|x| x.from.starts_with("Split") && x.to.contains("device-aware")),
        "expected a staged-Split -> device-aware crossover, got {line:?}"
    );
    // Winners at the extremes of the line agree with Figure 4.3b.
    let winners: Vec<_> = result.report.winners.iter().filter(|w| w.dest_nodes == 16).collect();
    assert!(winners.first().unwrap().winner.starts_with("Split+MD"));
    assert!(winners.last().unwrap().winner.contains("device-aware"));
}

#[test]
fn coarse_model_only_sweep_reaches_exascale_node_counts() {
    // The scale target behind the pruning/refinement levers: a model-only
    // sweep over an O(1k)-node machine stays cheap (no patterns, no
    // schedules), and the paper's regime structure extrapolates — staged
    // node-aware Split keeps the small band as the node count grows, while
    // device-aware still takes the largest sizes.
    let config = SweepConfig {
        grid: GridSpec {
            gens: vec![PatternGen::Uniform],
            dest_nodes: vec![64, 256, 1024],
            gpus_per_node: vec![4],
            nics: vec![1],
            sizes: (4..=20).step_by(4).map(|e| 1usize << e).collect(),
            n_msgs: 1024,
            dup_frac: 0.0,
        },
        sim: false,
        ..Default::default()
    };
    let exhaustive = run_sweep(&config).unwrap();
    assert_eq!(exhaustive.cells.len(), 3 * 5 * Strategy::all().len());

    let small_1k = exhaustive
        .report
        .regimes
        .iter()
        .find(|g| g.dest_nodes == 1024 && g.band == "small")
        .expect("1024-node small-band regime present");
    assert!(
        matches!(small_1k.winner_kind, StrategyKind::SplitMd | StrategyKind::SplitDd),
        "expected a Split strategy at 1024 nodes, got {}",
        small_1k.winner
    );
    let top_1k = exhaustive.report.winners.iter().filter(|w| w.dest_nodes == 1024).last().unwrap();
    assert!(top_1k.winner.contains("device-aware"), "largest size should stay device-aware: {}", top_1k.winner);

    // Refinement is purely model-driven, so it composes with model-only
    // sweeps: the coarse-to-fine pass must find the same boundaries.
    let refined = run_sweep(&SweepConfig { refine: 2, ..config.clone() }).unwrap();
    assert_eq!(exhaustive.report.crossovers, refined.report.crossovers, "refined crossovers diverged at scale");
    // Regime *winners* must agree; the band totals legitimately sum over
    // fewer lattice points in a refined run, so they are not compared.
    let regime_key =
        |g: &hetcomm::sweep::RegimeWinner| (g.gen, g.dest_nodes, g.gpus_per_node, g.nics, g.band, g.winner);
    assert_eq!(
        exhaustive.report.regimes.iter().map(regime_key).collect::<Vec<_>>(),
        refined.report.regimes.iter().map(regime_key).collect::<Vec<_>>(),
        "refined regime winners diverged at scale"
    );
}

#[test]
fn simulator_agrees_split_beats_standard_staged_moderate_sizes() {
    // The schedule-level cross-check: at moderate sizes with many messages,
    // the simulated Split+MD exchange beats simulated standard staged
    // communication (message conglomeration wins on the wire, not just in
    // the closed-form model).
    let config = SweepConfig {
        grid: GridSpec {
            gens: vec![PatternGen::Uniform],
            dest_nodes: vec![16],
            gpus_per_node: vec![4],
            nics: vec![1],
            sizes: vec![1024],
            n_msgs: 256,
            dup_frac: 0.0,
        },
        sim: true,
        ..Default::default()
    };
    let result = run_sweep(&config).unwrap();
    let sim_of = |kind: StrategyKind, transport: Transport| {
        result
            .cells
            .iter()
            .find(|c| c.strategy.kind == kind && c.strategy.transport == transport)
            .and_then(|c| c.sim_s)
            .expect("simulated")
    };
    let split = sim_of(StrategyKind::SplitMd, Transport::Staged);
    let standard = sim_of(StrategyKind::Standard, Transport::Staged);
    assert!(split < standard, "simulated Split+MD {split} !< simulated standard staged {standard}");
}
