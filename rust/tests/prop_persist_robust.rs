//! Parser-robustness properties: seeded byte corruption over every
//! versioned artifact format. The contract under attack bytes is uniform
//! across the persistence layers (docs/FORMATS.md): parsing returns a
//! descriptive `Err` or a *valid* value — never a panic, never a value
//! that fails its own invariants. Validity is checked the cheap way: any
//! `Ok` survivor must re-emit and re-parse cleanly.

use hetcomm::advisor::{DecisionSurface, SurfaceAxes};
use hetcomm::collective::CollectiveSurface;
use hetcomm::fault::{FaultEvent, FaultKind, FaultSpec};
use hetcomm::trace::{synthesize, TraceScenario};
use hetcomm::util::prop::{check, Gen};
use hetcomm::{advisor, collective, fault, trace};

/// One small exemplar per artifact family (all six schemas: surface
/// v1/v2/v3, trace.v1 with embedded faults, colsurface.v1, faults.v1).
fn artifacts() -> Vec<(&'static str, String)> {
    let axes = || SurfaceAxes {
        msgs: vec![32, 128],
        sizes: vec![1 << 8, 1 << 12, 1 << 16],
        dest_nodes: vec![4],
        gpus_per_node: vec![4],
    };
    let v1 = DecisionSurface::compile("lassen", axes(), 0.0).expect("lassen surface");
    let v2 = DecisionSurface::compile("frontier-4nic", axes(), 0.0).expect("frontier-4nic surface");
    let spec = FaultSpec {
        seed: 13,
        events: vec![
            FaultEvent { epoch: 1, kind: FaultKind::Slowdown { rail: 0, factor: 2.5 } },
            FaultEvent { epoch: 2, kind: FaultKind::Congestion { level: 3e-4 } },
        ],
    };
    let healthy = synthesize(TraceScenario::AmrDrift, "lassen", 3, 1, 5).expect("trace");
    let faulted = spec.attach(&healthy).expect("attachable schedule");
    let colsurface =
        CollectiveSurface::compile("lassen", 4, vec![2, 4], vec![512, 8192], 42).expect("collective surface");
    vec![
        ("surface.v1", advisor::persist::to_json(&v1)),
        ("surface.v2", advisor::persist::to_json(&v2)),
        ("surface.v3", advisor::persist::to_json_quant(&v2).expect("quantized surface")),
        ("trace.v1", trace::persist::to_json(&faulted)),
        ("colsurface.v1", collective::persist::to_json(&colsurface)),
        ("faults.v1", fault::persist::to_json(&spec)),
    ]
}

/// Seeded corruption: truncation, printable-byte splats, or digit
/// clobbering. All mutations stay ASCII (the artifacts are ASCII), so the
/// result is always a valid `&str` for the parsers.
fn corrupt(g: &mut Gen, text: &str) -> String {
    let mut bytes = text.as_bytes().to_vec();
    match g.usize(0, 3) {
        0 => {
            let cut = g.usize(0, bytes.len() + 1);
            bytes.truncate(cut);
        }
        1 => {
            for _ in 0..g.usize(1, 9) {
                let i = g.usize(0, bytes.len());
                bytes[i] = b' ' + g.usize(0, 95) as u8;
            }
        }
        _ => {
            let digits: Vec<usize> =
                bytes.iter().enumerate().filter(|(_, b)| b.is_ascii_digit()).map(|(i, _)| i).collect();
            for _ in 0..g.usize(1, 5) {
                bytes[digits[g.usize(0, digits.len())]] = b'x';
            }
        }
    }
    String::from_utf8(bytes).expect("ASCII mutations keep UTF-8 validity")
}

/// Parse `text` as artifact family `name`; an `Ok` must re-emit and
/// re-parse (i.e. the parser only accepts values its own writer can
/// reproduce). Returns an error only on the re-parse failure — a plain
/// parse `Err` on corrupted bytes is the expected outcome.
fn parse_and_verify(name: &str, text: &str) -> Result<(), String> {
    match name {
        "surface.v1" | "surface.v2" | "surface.v3" => {
            if let Ok(s) = advisor::persist::parse_json(text) {
                advisor::persist::parse_json(&advisor::persist::to_json(&s))
                    .map_err(|e| format!("accepted surface does not round-trip: {e}"))?;
            }
        }
        "trace.v1" => {
            if let Ok(t) = trace::persist::parse_json(text) {
                trace::persist::parse_json(&trace::persist::to_json(&t))
                    .map_err(|e| format!("accepted trace does not round-trip: {e}"))?;
            }
        }
        "colsurface.v1" => {
            if let Ok(s) = collective::persist::parse_json(text) {
                collective::persist::parse_json(&collective::persist::to_json(&s))
                    .map_err(|e| format!("accepted collective surface does not round-trip: {e}"))?;
            }
        }
        "faults.v1" => {
            if let Ok(s) = fault::persist::parse_json(text) {
                fault::persist::parse_json(&fault::persist::to_json(&s))
                    .map_err(|e| format!("accepted fault spec does not round-trip: {e}"))?;
            }
        }
        other => return Err(format!("unknown artifact family {other:?}")),
    }
    Ok(())
}

#[test]
fn corrupted_artifacts_never_panic_and_survivors_stay_valid() {
    let arts = artifacts();
    check("corruption -> Err or valid Ok", 240, |g| {
        let (name, original) = &arts[g.usize(0, arts.len())];
        let mutated = corrupt(g, original);
        parse_and_verify(name, &mutated)
    });
}

#[test]
fn pristine_artifacts_all_parse() {
    // the corruption property is vacuous if the baselines don't parse
    for (name, text) in artifacts() {
        parse_and_verify(name, &text).unwrap();
        let ok = match name {
            "surface.v1" | "surface.v2" | "surface.v3" => advisor::persist::parse_json(&text).is_ok(),
            "trace.v1" => trace::persist::parse_json(&text).is_ok(),
            "colsurface.v1" => collective::persist::parse_json(&text).is_ok(),
            "faults.v1" => fault::persist::parse_json(&text).is_ok(),
            _ => false,
        };
        assert!(ok, "{name} exemplar must parse");
    }
}

#[test]
fn adversarial_fragments_are_rejected_not_panicked() {
    // hand-picked nasties shared across all families
    let nasties = [
        "",
        "{",
        "null",
        "[]",
        "{}",
        "{\"schema\": \"hetcomm.surface.v1\"}",
        "{\"schema\": 42}",
        "{\"schema\": \"hetcomm.faults.v1\", \"seed\": \"1\", \"events\": 7}",
        "{\"schema\": \"hetcomm.faults.v1\", \"seed\": 1, \"events\": []}",
        "{\"schema\": \"hetcomm.trace.v1\", \"epochs\": [{}]}",
    ];
    for text in nasties {
        assert!(advisor::persist::parse_json(text).is_err());
        assert!(trace::persist::parse_json(text).is_err());
        assert!(collective::persist::parse_json(text).is_err());
        assert!(fault::persist::parse_json(text).is_err());
    }
}
