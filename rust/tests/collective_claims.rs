//! Integration: the collective layer's headline claims end to end —
//! (a) the locality-aware alltoallv beats the standard algorithm in the
//! high-node-count / small-message regime, in the model (>= 3% margin, the
//! CI gate) and in the simulator; (b) the standard algorithm keeps the
//! low-node-count / large-message corner; (c) seeded runs emit byte-identical
//! JSON/CSV regardless of thread count; (d) the legacy strategy sweep is
//! untouched by the collective axis.

use hetcomm::collective::emit as col_emit;
use hetcomm::collective::{
    lower, run_collective, Collective, CollectiveAlgorithm, CollectiveConfig, CollectiveGrid, CollectiveSpec,
};
use hetcomm::collective::algorithm_time;
use hetcomm::params::lassen_params;
use hetcomm::sweep::{emit as sweep_emit, run_sweep, GridSpec, PatternGen, SweepConfig};
use hetcomm::topology::machines::lassen;

fn gate_config(nodes: usize, size: usize, sim: bool) -> CollectiveConfig {
    CollectiveConfig {
        grid: CollectiveGrid {
            collectives: vec![Collective::Alltoallv],
            algorithms: vec![CollectiveAlgorithm::Standard, CollectiveAlgorithm::Locality],
            nodes: vec![nodes],
            gpus_per_node: vec![4],
            sizes: vec![size],
        },
        seed: 42,
        threads: 1,
        sim,
        ..Default::default()
    }
}

/// Run the grid once and return (model_s, sim_s) for standard and locality.
fn std_vs_locality(config: &CollectiveConfig) -> ((f64, Option<f64>), (f64, Option<f64>)) {
    let r = run_collective(config).unwrap();
    let pick = |alg: CollectiveAlgorithm| {
        let c = r.cells.iter().find(|c| c.algorithm == alg).expect("cell present");
        (c.model_s, c.sim_s)
    };
    (pick(CollectiveAlgorithm::Standard), pick(CollectiveAlgorithm::Locality))
}

/// The CI regime gate, in-repo: at 32 nodes x 4 GPUs and 512 B blocks the
/// locality-aware alltoallv beats standard by at least 3% in the model, and
/// the simulator agrees on the direction.
#[test]
fn locality_alltoallv_wins_high_node_count_small_messages() {
    let ((std_model, std_sim), (loc_model, loc_sim)) = std_vs_locality(&gate_config(32, 512, true));

    let margin = (std_model - loc_model) / std_model;
    assert!(
        margin >= 0.03,
        "model margin {margin:.4} below the 3% gate (standard {std_model:e}, locality {loc_model:e})"
    );
    let (std_sim, loc_sim) = (std_sim.unwrap(), loc_sim.unwrap());
    assert!(
        loc_sim < std_sim,
        "simulator disagrees with the model: locality {loc_sim:e} >= standard {std_sim:e}"
    );
}

/// The crossover's other side: at 2 nodes and 512 KiB blocks the extra
/// staging hops cost more than the saved messages, and standard wins.
#[test]
fn standard_keeps_the_low_node_count_large_message_corner() {
    let ((std_model, _), (loc_model, _)) = std_vs_locality(&gate_config(2, 512 << 10, false));
    assert!(
        std_model < loc_model,
        "standard must win at 2 nodes / 512 KiB: standard {std_model:e}, locality {loc_model:e}"
    );
}

/// The same claim straight through the model layer, without the sweep
/// machinery: compose the Table 6 primitives over the lowered stages.
#[test]
fn model_layer_reproduces_the_crossover() {
    let p = lassen_params();
    let cell = |nodes: usize, size: usize, alg: CollectiveAlgorithm| {
        let m = lassen(nodes);
        let direct = CollectiveSpec::new(Collective::Alltoallv, size, 42).materialize(&m);
        algorithm_time(&m, &p, &lower(Collective::Alltoallv, alg, &m, &direct))
    };
    let std_small = cell(32, 512, CollectiveAlgorithm::Standard);
    let loc_small = cell(32, 512, CollectiveAlgorithm::Locality);
    assert!((std_small - loc_small) / std_small >= 0.03);
    assert!(cell(2, 512 << 10, CollectiveAlgorithm::Standard) < cell(2, 512 << 10, CollectiveAlgorithm::Locality));
}

/// Seeded collective runs are byte-deterministic: the JSON and CSV artifacts
/// are identical across repeated runs and across thread counts.
#[test]
fn seeded_artifacts_are_byte_identical() {
    let mk = |threads: usize| CollectiveConfig {
        grid: CollectiveGrid::tiny(),
        seed: 7,
        threads,
        sim: true,
        ..Default::default()
    };
    let a = run_collective(&mk(1)).unwrap();
    let b = run_collective(&mk(2)).unwrap();
    assert_eq!(col_emit::to_json(&a), col_emit::to_json(&b), "thread count leaked into the JSON artifact");
    assert_eq!(col_emit::to_csv(&a), col_emit::to_csv(&b), "thread count leaked into the CSV artifact");
    assert!(col_emit::to_json(&a).contains("\"schema\": \"hetcomm.collective.v1\""));
}

/// Grids without a collective axis are untouched: the legacy strategy sweep
/// emits no collective fields at all.
#[test]
fn legacy_sweep_has_no_collective_fields() {
    let config = SweepConfig {
        grid: GridSpec {
            gens: vec![PatternGen::Uniform],
            dest_nodes: vec![4],
            gpus_per_node: vec![4],
            nics: vec![1],
            sizes: vec![256, 4096],
            n_msgs: 64,
            dup_frac: 0.0,
        },
        sim: false,
        ..Default::default()
    };
    let json = sweep_emit::to_json(&run_sweep(&config).unwrap());
    assert!(!json.contains("collective"), "legacy sweep output must not grow collective fields");
}
